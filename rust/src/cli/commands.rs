//! Non-figure CLI commands: factor / gft / serve / schedule / bench /
//! bakeoff / eigen / bench-apply.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::bail;

use super::figures::{budget, random_gplan, random_tplan};
use super::Args;
use crate::baselines::{
    factor_orthonormal, greedy_givens, lowrank_error_symmetric, truncated_jacobi,
};
use crate::factor::{
    load_checkpoint, mat_checksum, save_gen_checkpoint, save_sym_checkpoint, verify_matrix,
    CheckpointMeta, FactorExec, GenCheckpoint, GenRunControl, GeneralFactorizer, GeneralOptions,
    LoadedState, SymCheckpoint, SymFactorizer, SymOptions, SymRunControl,
};
use crate::graphs::{self, RealWorldGraph};
use crate::linalg::{eigh, Mat, Rng64};
use crate::ops::{FilterOp, SpectralKernel, TopK, WaveletBank};
use crate::plan::{Direction, ExecPolicy, FastOperator, Plan};
use crate::runtime::autotune::{self, TuneEffort, TuneProfile, TunedConfig, WallTimer};
use crate::serve::{
    net, refactor_plan, Backend, Coordinator, NativeGftBackend, PjrtGftBackend, PlanRegistry,
    RefactorJob, RefactorOptions, RefactorWorker, ServeConfig, TransformDirection,
};
use crate::transforms::{certify_g, simd, ExecConfig, GChain, KernelIsa, SignalBlock};

/// Parse the `--kernel auto|scalar|avx2|avx512|neon` flag: `auto` (the
/// default) keeps the process default ([`simd::default_kernel`] —
/// `FASTES_KERNEL`, else runtime detection); an explicit ISA must be
/// supported on this host. A non-auto choice is also pinned as the
/// process default so the config-less `Seq` engine honours it.
fn kernel_from_args(a: &Args) -> crate::Result<Option<KernelIsa>> {
    let name = a.get_str("kernel", "auto");
    if name == "auto" {
        return Ok(None);
    }
    match KernelIsa::from_name(&name) {
        Some(isa) if isa.is_supported() => {
            simd::set_default_kernel(isa);
            Ok(Some(isa))
        }
        Some(isa) => bail!(
            "--kernel {name}: the {} kernel is not supported on this host (available: {})",
            isa.as_str(),
            KernelIsa::available().iter().map(|k| k.as_str()).collect::<Vec<_>>().join("|")
        ),
        None => bail!("--kernel must be auto|scalar|avx2|avx512|neon (got {name})"),
    }
}

/// Apply the common executor flags (`--threads`, `--min-work`,
/// `--layer-min-work`, `--tile`, `--kernel`) on top of `base` (which
/// already honours `FASTES_*` environment overrides).
fn exec_config_from_args_base(a: &Args, base: ExecConfig) -> crate::Result<ExecConfig> {
    Ok(ExecConfig {
        threads: a.get("threads", base.threads)?.max(1),
        min_work: a.get("min-work", base.min_work)?,
        layer_min_work: a.get("layer-min-work", base.layer_min_work)?,
        tile_cols: a.get("tile", base.tile_cols)?.max(1),
        kernel: kernel_from_args(a)?.or(base.kernel),
    })
}

/// Executor flags over the pooled defaults.
fn exec_config_from_args(a: &Args) -> crate::Result<ExecConfig> {
    exec_config_from_args_base(a, ExecConfig::pooled())
}

/// Build the [`ExecPolicy`] selected by `--exec seq|spawn|pool`, giving
/// each engine its own tunable defaults under the shared flag overrides.
fn exec_policy_from_args(a: &Args, exec: &str) -> crate::Result<ExecPolicy> {
    Ok(match exec {
        "seq" => {
            // Seq carries no config, but --kernel must still validate and
            // pin the process default the config-less engine dispatches on
            kernel_from_args(a)?;
            ExecPolicy::Seq
        }
        "spawn" => ExecPolicy::Spawn(exec_config_from_args_base(a, ExecConfig::spawn())?),
        "pool" => ExecPolicy::Pool(exec_config_from_args(a)?),
        "auto" => {
            // resolved by the startup micro-calibration on first apply;
            // --kernel still validates and pins the process default
            kernel_from_args(a)?;
            ExecPolicy::Auto
        }
        other => bail!("--exec must be seq|spawn|pool|auto (got {other})"),
    })
}

/// Honour `--save-plan PATH`: persist a compiled plan as a versioned
/// `.fastplan` artifact that `fastes serve --plan PATH` can load without
/// refactorizing. Takes the plan lazily — without the flag no plan is
/// compiled at all.
fn maybe_save_plan(a: &Args, plan: impl FnOnce() -> Arc<Plan>) -> crate::Result<()> {
    let path = a.get_str("save-plan", "");
    if path.is_empty() {
        return Ok(());
    }
    let plan = plan();
    plan.save(&path)?;
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!(
        "wrote {path}: kind={:?} n={} stages={} superstages={} ({bytes} bytes)",
        plan.kind(),
        plan.n(),
        plan.len(),
        plan.num_superstages()
    );
    Ok(())
}

/// Execution knobs of the factorizer itself: `--threads` /
/// `--factor-min-work` over the `FASTES_FACTOR_*` environment defaults.
/// The thread count never changes the resulting chain — the parallel
/// factorizer is bitwise-identical to the sequential one.
fn factor_exec_from_args(a: &Args) -> crate::Result<FactorExec> {
    let base = FactorExec::default();
    Ok(FactorExec {
        threads: a.get("threads", base.threads)?.max(1),
        min_work: a.get("factor-min-work", base.min_work)?,
    })
}

/// `fastes factor` — factor a random matrix and report accuracy/time.
/// `--checkpoint BASE` periodically persists `BASE.fastplan` +
/// `BASE.fastckpt` (every `--checkpoint-every` progress steps) so a
/// killed or `--halt-after`-stopped run can be continued with
/// `--resume BASE`, reproducing the uninterrupted result bitwise.
pub fn factor(a: &Args) -> crate::Result<()> {
    let resume = a.get_str("resume", "");
    if !resume.is_empty() {
        return factor_resume(a, &resume);
    }
    if a.has("error-budget") {
        return factor_to_budget(a);
    }
    if a.has("max-g") {
        bail!("--max-g only bounds a budgeted run; it needs --error-budget EPS");
    }
    let n: usize = a.get("n", 128)?;
    let g: usize = a.get("budget", budget(2, n))?;
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let kind = a.get_str("kind", "sym");
    let exec = factor_exec_from_args(a)?;
    let ck_base = a.get_str("checkpoint", "");
    let mut every: usize = a.get("checkpoint-every", 0)?;
    if !ck_base.is_empty() && every == 0 {
        every = 100;
    }
    if ck_base.is_empty() && every != 0 {
        bail!("--checkpoint-every needs --checkpoint BASE");
    }
    let halt_after = match a.has("halt-after") {
        true => Some(a.get("halt-after", 0usize)?),
        false => None,
    };
    if halt_after.is_some() && ck_base.is_empty() {
        bail!("--halt-after without --checkpoint BASE would discard the partial run");
    }
    let mut rng = Rng64::new(seed);
    let x = Mat::randn(n, n, &mut rng);
    let t0 = Instant::now();
    match kind.as_str() {
        "sym" | "psd" => {
            let s = if kind == "psd" { x.matmul(&x.transpose()) } else { &x + &x.transpose() };
            let opts = SymOptions {
                max_sweeps: sweeps,
                eps: a.get("eps", SymOptions::default().eps)?,
                full_update: a.has("full-update"),
                exec,
                ..Default::default()
            };
            let meta = CheckpointMeta {
                kind: "sym".to_string(),
                budget: g,
                max_sweeps: opts.max_sweeps,
                eps: opts.eps,
                full_update: opts.full_update,
                checkpoint_every: every,
                problem_n: n,
                problem_seed: seed,
                problem_kind: kind.clone(),
                matrix_checksum: mat_checksum(&s),
            };
            let f = if ck_base.is_empty() {
                SymFactorizer::new(&s, g, opts).run()
            } else {
                let base = PathBuf::from(&ck_base);
                let mut ctrl = SymRunControl {
                    checkpoint_every: every,
                    halt_after,
                    on_checkpoint: Some(Box::new(|ck: &SymCheckpoint| {
                        if let Err(e) = save_sym_checkpoint(&base, &meta, ck) {
                            eprintln!("checkpoint write failed: {e:#}");
                        }
                    })),
                };
                SymFactorizer::new(&s, g, opts).run_controlled(&mut ctrl)
            };
            println!(
                "sym n={n} g={g} init_rel={:.4} final_rel={:.4} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                (f.init_objective / s.fro_norm_sq()).sqrt(),
                f.relative_error(&s),
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
            if f.halted {
                println!(
                    "halted early (--halt-after): {} factors, {} sweeps so far — \
                     resume with: fastes factor --resume {ck_base}",
                    f.chain.len(),
                    f.sweeps_run
                );
            }
            maybe_save_plan(a, || f.plan())?;
        }
        "gen" => {
            let opts = GeneralOptions {
                max_sweeps: sweeps,
                eps: a.get("eps", GeneralOptions::default().eps)?,
                full_update: a.has("full-update"),
                exec,
                ..Default::default()
            };
            let meta = CheckpointMeta {
                kind: "gen".to_string(),
                budget: g,
                max_sweeps: opts.max_sweeps,
                eps: opts.eps,
                full_update: opts.full_update,
                checkpoint_every: every,
                problem_n: n,
                problem_seed: seed,
                problem_kind: kind.clone(),
                matrix_checksum: mat_checksum(&x),
            };
            let f = if ck_base.is_empty() {
                GeneralFactorizer::new(&x, g, opts).run()
            } else {
                let base = PathBuf::from(&ck_base);
                let mut ctrl = GenRunControl {
                    checkpoint_every: every,
                    halt_after,
                    on_checkpoint: Some(Box::new(|ck: &GenCheckpoint| {
                        if let Err(e) = save_gen_checkpoint(&base, &meta, ck) {
                            eprintln!("checkpoint write failed: {e:#}");
                        }
                    })),
                };
                GeneralFactorizer::new(&x, g, opts).run_controlled(&mut ctrl)
            };
            println!(
                "gen n={n} m={g} init_rel={:.4} final_rel={:.4} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                (f.init_objective / x.fro_norm_sq()).sqrt(),
                f.relative_error(&x),
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
            if f.halted {
                println!(
                    "halted early (--halt-after): {} factors, {} sweeps so far — \
                     resume with: fastes factor --resume {ck_base}",
                    f.chain.len(),
                    f.sweeps_run
                );
            }
            maybe_save_plan(a, || f.plan())?;
        }
        other => bail!("--kind must be sym|psd|gen (got {other})"),
    }
    Ok(())
}

/// `fastes factor --error-budget EPS` — grow the transform budget
/// (doubling from `--budget`, capped at `--max-g`) until the measured
/// relative error `‖S − Ū diag(s̄) Ūᵀ‖_F / ‖S‖_F` meets EPS, then report
/// the resulting error certificate. With `--save-plan` the artifact is a
/// version-3 `.fastplan` carrying that certificate, which
/// `fastes serve --max-error` enforces at routing time.
fn factor_to_budget(a: &Args) -> crate::Result<()> {
    for k in ["checkpoint", "checkpoint-every", "halt-after"] {
        if a.has(k) {
            bail!(
                "--{k} conflicts with --error-budget (the budgeted run drives the \
                 checkpoint machinery internally to grow g)"
            );
        }
    }
    let eps: f64 = a.get("error-budget", 0.0)?;
    if !(eps.is_finite() && eps > 0.0) {
        bail!("--error-budget must be a positive relative error (got {eps})");
    }
    let n: usize = a.get("n", 128)?;
    let g_start: usize = a.get("budget", budget(2, n))?;
    let g_max: usize = a.get("max-g", (n * (n - 1) / 2).max(g_start))?;
    if g_max < g_start {
        bail!("--max-g {g_max} is below the starting --budget {g_start}");
    }
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let kind = a.get_str("kind", "sym");
    let exec = factor_exec_from_args(a)?;
    let mut rng = Rng64::new(seed);
    let x = Mat::randn(n, n, &mut rng);
    let t0 = Instant::now();
    match kind.as_str() {
        "sym" | "psd" => {
            let s = if kind == "psd" { x.matmul(&x.transpose()) } else { &x + &x.transpose() };
            let opts = SymOptions {
                max_sweeps: sweeps,
                eps: a.get("eps", SymOptions::default().eps)?,
                full_update: a.has("full-update"),
                exec,
                ..Default::default()
            };
            let (f, cert) = SymFactorizer::run_to_budget(&s, eps, g_start, g_max, opts);
            let met = if cert.meets(eps) { "met" } else { "NOT met (g capped)" };
            println!(
                "sym n={n} error-budget={eps:.3e} {met}: g={} rel_err={:.6e} \
                 fro_err={:.3e} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                cert.g,
                cert.rel_err,
                cert.fro_err,
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
            maybe_save_plan(a, || f.certified_plan(&s))?;
        }
        "gen" => {
            let opts = GeneralOptions {
                max_sweeps: sweeps,
                eps: a.get("eps", GeneralOptions::default().eps)?,
                full_update: a.has("full-update"),
                exec,
                ..Default::default()
            };
            let (f, cert) = GeneralFactorizer::run_to_budget(&x, eps, g_start, g_max, opts);
            let met = if cert.meets(eps) { "met" } else { "NOT met (m capped)" };
            println!(
                "gen n={n} error-budget={eps:.3e} {met}: m={} rel_err={:.6e} \
                 fro_err={:.3e} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                cert.g,
                cert.rel_err,
                cert.fro_err,
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
            maybe_save_plan(a, || f.certified_plan(&x))?;
        }
        other => bail!("--kind must be sym|psd|gen (got {other})"),
    }
    Ok(())
}

/// `fastes factor --resume BASE` — load `BASE.fastplan` +
/// `BASE.fastckpt`, regenerate and verify the seeded input matrix, then
/// continue the run exactly where it stopped. The problem and options
/// are pinned by the checkpoint; only execution knobs (`--threads`) and
/// the checkpoint cadence/destination may be overridden.
fn factor_resume(a: &Args, base: &str) -> crate::Result<()> {
    for k in ["n", "budget", "seed", "kind", "sweeps", "eps", "full-update"] {
        if a.has(k) {
            bail!("--{k} conflicts with --resume (the checkpoint pins the problem and options)");
        }
    }
    let (mut meta, state) = load_checkpoint(&PathBuf::from(base))?;
    meta.checkpoint_every = a.get("checkpoint-every", meta.checkpoint_every)?;
    let write_base = PathBuf::from(a.get_str("checkpoint", base));
    let every = meta.checkpoint_every;
    let halt_after = match a.has("halt-after") {
        true => Some(a.get("halt-after", 0usize)?),
        false => None,
    };
    let exec = factor_exec_from_args(a)?;
    let n = meta.problem_n;
    let g = meta.budget;
    let mut rng = Rng64::new(meta.problem_seed);
    let x = Mat::randn(n, n, &mut rng);
    let t0 = Instant::now();
    match state {
        LoadedState::Sym(ck) => {
            let s = if meta.problem_kind == "psd" {
                x.matmul(&x.transpose())
            } else {
                &x + &x.transpose()
            };
            verify_matrix(&meta, &s)
                .map_err(|e| e.context(format!("--resume {base}")))?;
            let opts = SymOptions {
                max_sweeps: meta.max_sweeps,
                eps: meta.eps,
                full_update: meta.full_update,
                exec,
                ..Default::default()
            };
            println!(
                "resuming {base}: sym n={n} g={g} steps_done={} in_init={}",
                ck.steps_done, ck.in_init
            );
            let mut ctrl = SymRunControl {
                checkpoint_every: every,
                halt_after,
                on_checkpoint: Some(Box::new(|c: &SymCheckpoint| {
                    if let Err(e) = save_sym_checkpoint(&write_base, &meta, c) {
                        eprintln!("checkpoint write failed: {e:#}");
                    }
                })),
            };
            let f = SymFactorizer::new(&s, g, opts).resume(ck, &mut ctrl);
            drop(ctrl);
            println!(
                "sym n={n} g={g} final_rel={:.4} sweeps={} flops/apply={} elapsed={:.2?}",
                f.relative_error(&s),
                f.sweeps_run,
                f.chain.flops(),
                t0.elapsed()
            );
            if f.halted {
                println!("halted again — resume with: fastes factor --resume {base}");
            }
            maybe_save_plan(a, || f.plan())?;
        }
        LoadedState::Gen(ck) => {
            verify_matrix(&meta, &x)
                .map_err(|e| e.context(format!("--resume {base}")))?;
            let opts = GeneralOptions {
                max_sweeps: meta.max_sweeps,
                eps: meta.eps,
                full_update: meta.full_update,
                exec,
                ..Default::default()
            };
            println!(
                "resuming {base}: gen n={n} m={g} steps_done={} in_init={}",
                ck.steps_done, ck.in_init
            );
            let mut ctrl = GenRunControl {
                checkpoint_every: every,
                halt_after,
                on_checkpoint: Some(Box::new(|c: &GenCheckpoint| {
                    if let Err(e) = save_gen_checkpoint(&write_base, &meta, c) {
                        eprintln!("checkpoint write failed: {e:#}");
                    }
                })),
            };
            let f = GeneralFactorizer::new(&x, g, opts).resume(ck, &mut ctrl);
            drop(ctrl);
            println!(
                "gen n={n} m={g} final_rel={:.4} sweeps={} flops/apply={} elapsed={:.2?}",
                f.relative_error(&x),
                f.sweeps_run,
                f.chain.flops(),
                t0.elapsed()
            );
            if f.halted {
                println!("halted again — resume with: fastes factor --resume {base}");
            }
            maybe_save_plan(a, || f.plan())?;
        }
    }
    Ok(())
}

/// `fastes refactor --from PLAN` — warm-start refactorization against a
/// drifted graph. Regenerates the base graph from `--graph`/`--seed`,
/// applies `--drift K` deterministic edge updates (`--drift-seed`), then
/// re-polishes the donor plan's chain against the drifted Laplacian:
/// the Lemma-1 spectrum and the error certificate are re-measured
/// against the drifted matrix, never inherited from the artifact. With
/// `--error-budget EPS` the chain also grows (doubling, capped at
/// `--max-g`) until the re-measured certificate meets EPS.
/// `--compare-cold` times a from-scratch budgeted run on the same
/// drifted matrix so the warm-start saving is visible; `--save-plan`
/// writes the re-certified artifact.
pub fn refactor(a: &Args) -> crate::Result<()> {
    let from = a.get_str("from", "");
    if from.is_empty() {
        bail!("refactor needs --from FILE.fastplan (the donor plan to warm-start)");
    }
    let donor = Plan::load(&from)?;
    let n = donor.n();
    let donor_g = donor.len();
    println!(
        "donor {from}: kind={:?} n={n} stages={donor_g} checksum={:016x}",
        donor.kind(),
        donor.content_checksum()
    );
    let n_flag: usize = a.get("n", n)?;
    if n_flag != n {
        bail!("--n {n_flag} conflicts with the donor plan (n={n})");
    }
    let seed: u64 = a.get("seed", 1)?;
    let drift_steps: usize = a.get("drift", 8)?;
    let drift_seed: u64 = a.get("drift-seed", seed)?;
    let budget_eps = match a.has("error-budget") {
        true => {
            let eps: f64 = a.get("error-budget", 0.0)?;
            if !(eps.is_finite() && eps > 0.0) {
                bail!("--error-budget must be a positive relative error (got {eps})");
            }
            Some(eps)
        }
        false => None,
    };
    if a.has("max-g") && budget_eps.is_none() {
        bail!("--max-g only bounds a budgeted refactor; it needs --error-budget EPS");
    }
    let opts = RefactorOptions {
        budget: budget_eps,
        max_g: match a.has("max-g") {
            true => Some(a.get("max-g", 0usize)?.max(1)),
            false => None,
        },
        max_error: None,
        max_sweeps: a.get("sweeps", RefactorOptions::default().max_sweeps)?,
        exec: factor_exec_from_args(a)?,
    };

    // regenerate the base graph the donor was factored from, then drift
    let mut rng = Rng64::new(seed);
    let mut graph = build_graph_sized(a, n, &mut rng)?;
    if graph.n != n {
        bail!(
            "--graph {} regenerates n={} vertices, but the donor plan is for n={n}",
            a.get_str("graph", "community"),
            graph.n
        );
    }
    let edges_before = graph.num_edges();
    let updates = graphs::drift(&mut graph, drift_steps, drift_seed);
    let l = graph.laplacian();
    println!(
        "drifted {} graph n={n}: {} edge updates, |E| {edges_before} → {}",
        a.get_str("graph", "community"),
        updates.len(),
        graph.num_edges()
    );

    let t0 = Instant::now();
    let r = refactor_plan(&donor, &l, &opts)?;
    let warm_s = t0.elapsed().as_secs_f64();
    let met = match budget_eps {
        Some(eps) if r.certificate.meets(eps) => " (budget met)",
        Some(_) => " (budget NOT met — g capped)",
        None => "",
    };
    println!(
        "warm refactor: g={} rel_err={:.6e} fro_err={:.3e} sweeps={} growth_rounds={} \
         factors_added={} elapsed={warm_s:.3}s{met}",
        r.g,
        r.certificate.rel_err,
        r.certificate.fro_err,
        r.stats.total_sweeps,
        r.stats.growth_rounds,
        r.stats.factors_added
    );

    if a.has("compare-cold") {
        let Some(eps) = budget_eps else {
            bail!("--compare-cold compares iterations-to-budget; it needs --error-budget EPS");
        };
        let sym_opts = SymOptions {
            max_sweeps: opts.max_sweeps,
            exec: opts.exec,
            ..Default::default()
        };
        let g_start = budget(a.get("alpha", 2)?, n);
        let g_max = opts.max_g.unwrap_or_else(|| donor_g.saturating_mul(4).max(1));
        let t0 = Instant::now();
        let (cf, ccert, cstats) =
            SymFactorizer::run_to_budget_stats(&l, eps, g_start, g_max.max(g_start), sym_opts);
        let cold_s = t0.elapsed().as_secs_f64();
        println!(
            "cold baseline: g={} rel_err={:.6e} sweeps={} growth_rounds={} elapsed={cold_s:.3}s",
            cf.chain.len(),
            ccert.rel_err,
            cstats.total_sweeps,
            cstats.growth_rounds
        );
        println!(
            "warm vs cold: {}/{} sweeps ({:.2}x), {:.2}x wall-clock",
            r.stats.total_sweeps,
            cstats.total_sweeps,
            cstats.total_sweeps as f64 / r.stats.total_sweeps.max(1) as f64,
            cold_s / warm_s.max(1e-12)
        );
    }

    maybe_save_plan(a, || Arc::clone(&r.plan))?;
    Ok(())
}

fn build_graph(a: &Args, rng: &mut Rng64) -> crate::Result<graphs::Graph> {
    let n: usize = a.get("n", 128)?;
    build_graph_sized(a, n, rng)
}

/// [`build_graph`] with the vertex count pinned by the caller instead of
/// `--n` (the `refactor` command takes it from the donor plan).
fn build_graph_sized(a: &Args, n: usize, rng: &mut Rng64) -> crate::Result<graphs::Graph> {
    let name = a.get_str("graph", "community");
    let scale: f64 = a.get("scale", 0.25)?;
    Ok(match name.as_str() {
        "community" => graphs::community(n, rng),
        "er" | "erdos-renyi" => graphs::erdos_renyi(n, 0.3, rng),
        "sensor" => graphs::sensor(n, rng),
        "ring" => graphs::ring(n),
        "masked-grid" => {
            // square-ish grid covering n vertices; cells beyond n plus a
            // random --mask fraction are masked out (left isolated) —
            // the irregular-domain shape spectral operators run on
            let rows = ((n as f64).sqrt().round() as usize).max(1);
            let cols = (n + rows - 1) / rows;
            let p: f64 = a.get("mask", 0.2)?;
            if !(0.0..1.0).contains(&p) {
                bail!("--mask must be in [0, 1) (got {p})");
            }
            let mask: Vec<bool> =
                (0..rows * cols).map(|i| i < n && !rng.bernoulli(p)).collect();
            graphs::masked_grid(rows, cols, &mask)
        }
        "minnesota" => graphs::real_world_substitute(RealWorldGraph::Minnesota, scale, rng),
        "protein" => graphs::real_world_substitute(RealWorldGraph::HumanProtein, scale, rng),
        "email" => graphs::real_world_substitute(RealWorldGraph::Email, scale, rng),
        "facebook" => graphs::real_world_substitute(RealWorldGraph::Facebook, scale, rng),
        other => bail!("unknown --graph {other}"),
    })
}

/// `fastes gft` — build a graph, factor its Laplacian, report accuracy.
pub fn gft(a: &Args) -> crate::Result<()> {
    let seed: u64 = a.get("seed", 1)?;
    let alpha: usize = a.get("alpha", 2)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let mut rng = Rng64::new(seed);
    let graph = build_graph(a, &mut rng)?;
    let n = graph.n;
    let g = budget(alpha, n);
    println!("graph n={n} |E|={} directed={}", graph.num_edges(), a.has("directed"));
    let t0 = Instant::now();
    if a.has("directed") {
        let d = graph.randomly_directed(&mut rng);
        let l = d.laplacian();
        let f = GeneralFactorizer::new(
            &l,
            g,
            GeneralOptions { max_sweeps: sweeps, ..Default::default() },
        )
        .run();
        println!(
            "T-chain m={} rel_err={:.4} flops/apply={} (dense {}) elapsed={:.2?}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n,
            t0.elapsed()
        );
        maybe_save_plan(a, || f.plan())?;
    } else {
        let l = graph.laplacian();
        let f = SymFactorizer::new(
            &l,
            g,
            SymOptions { max_sweeps: sweeps, ..Default::default() },
        )
        .run();
        println!(
            "G-chain g={} rel_err={:.4} flops/apply={} (dense {}) elapsed={:.2?}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n,
            t0.elapsed()
        );
        maybe_save_plan(a, || f.plan())?;
    }
    Ok(())
}

/// `fastes filter` — run the fused spectral-operator workloads on a
/// factored fast eigenspace: a kernel graph filter (default), a Hammond
/// wavelet bank (`--wavelet J`) or top-k / threshold spectral
/// compression (`--topk K`, `--threshold T`). The operator comes from a
/// saved version-2 artifact (`--plan FILE.fastplan`, spectrum attached)
/// or an in-process factorization of a `--graph` Laplacian (the Lemma-1
/// spectrum is attached automatically). The filter path verifies the
/// fused single-pass route **bitwise** against the unfused
/// adjoint → row-scale → forward reference and reports the flop
/// accounting of both.
pub fn filter(a: &Args) -> crate::Result<()> {
    let seed: u64 = a.get("seed", 1)?;
    let batch: usize = a.get("batch", 8)?;
    let exec = a.get_str("exec", "seq");
    let policy = exec_policy_from_args(a, &exec)?;
    let mut rng = Rng64::new(seed);
    let plan_path = a.get_str("plan", "");
    let plan: Arc<Plan> = if plan_path.is_empty() {
        let alpha: usize = a.get("alpha", 2)?;
        let sweeps: usize = a.get("sweeps", 2)?;
        let graph = build_graph(a, &mut rng)?;
        let n = graph.n;
        let l = graph.laplacian();
        let g = budget(alpha, n);
        println!(
            "factoring {} graph n={n} |E|={} with g={g}…",
            a.get_str("graph", "community"),
            graph.num_edges()
        );
        let f =
            SymFactorizer::new(&l, g, SymOptions { max_sweeps: sweeps, ..Default::default() })
                .run();
        println!("factored: rel_err={:.4}", f.relative_error(&l));
        // SymFactorization::plan() attaches the Lemma-1 spectrum, so
        // kernel-based responses resolve without a saved v2 artifact
        f.plan()
    } else {
        if a.has("n") || a.has("graph") || a.has("alpha") {
            bail!("--n/--graph/--alpha conflict with --plan (the artifact fixes the operator)");
        }
        let plan = Plan::load(&plan_path)?;
        println!(
            "loaded {plan_path}: kind={:?} n={} stages={} spectrum={}",
            plan.kind(),
            plan.n(),
            plan.len(),
            if plan.spectrum().is_some() { "attached (v2)" } else { "none (v1)" }
        );
        plan
    };
    let n = plan.n();
    let signals: Vec<Vec<f32>> =
        (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
    let block = SignalBlock::from_signals(&signals)?;

    // --topk K / --threshold T: sparse spectral compression
    let k: usize = a.get("topk", 0)?;
    let thr: f32 = a.get("threshold", 0.0f32)?;
    if k > 0 || thr > 0.0 {
        let rule = TopK { k, threshold: thr };
        let t0 = Instant::now();
        let payloads = rule.compress_spectral(&plan, &block, &policy)?;
        let elapsed = t0.elapsed();
        // reference spectral coefficients for the retained-energy report
        let mut spectral = block.clone();
        plan.apply(&mut spectral, Direction::Adjoint, &ExecPolicy::Seq)?;
        let b = spectral.batch;
        for (j, p) in payloads.iter().enumerate() {
            let total: f64 = (0..n)
                .map(|i| {
                    let v = spectral.data[i * b + j] as f64;
                    v * v
                })
                .sum();
            let kept: f64 = p.values.iter().map(|&v| (v as f64) * (v as f64)).sum();
            println!(
                "signal {j}: kept {}/{n} coefficients ({} B sparse vs {} B dense, \
                 {:.1}% of spectral energy)",
                p.len(),
                8 * p.len(),
                4 * n,
                100.0 * kept / total.max(f64::MIN_POSITIVE)
            );
        }
        println!("compressed batch={batch} in {elapsed:.2?} (k={k}, threshold={thr})");
        return Ok(());
    }

    // --wavelet J: Hammond bank over the shared-prefix DAG
    let j: usize = a.get("wavelet", 0)?;
    if j > 0 {
        let bank = WaveletBank::hammond(Arc::clone(&plan), j)?;
        let t0 = Instant::now();
        let bands = bank.analyze(&block, &policy)?;
        let elapsed = t0.elapsed();
        let plan_flops = FastOperator::flops(plan.as_ref());
        println!(
            "Hammond bank: {} bands (scaling + {j} wavelets) analyzed batch={batch} in \
             {elapsed:.2?}",
            bank.bands()
        );
        println!(
            "shared-prefix flops/apply {} vs {} as independent filters \
             ({} reverse traversals saved)",
            bank.flops(),
            bank.bands() * (2 * plan_flops + n),
            bank.bands() - 1
        );
        for (b, band) in bands.iter().enumerate() {
            let energy: f64 = band.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let label = if b == 0 {
                "scaling".to_string()
            } else {
                format!("scale {:.4}", bank.scales()[b - 1])
            };
            println!("band {b} ({label}): energy {energy:.4}");
        }
        return Ok(());
    }

    // default: one spectral filter, fused vs unfused
    let response = a.get_str("response", "heat");
    let param: f64 = a.get("param", 0.5)?;
    let kernel = SpectralKernel::from_name(&response, param)?;
    let op = FilterOp::from_kernel(Arc::clone(&plan), &kernel)?;
    println!(
        "filter {response}({param}) n={n} batch={batch}: fused flops/apply {} \
         (= 2·{plan_flops} + {n}, one reverse + one forward traversal)",
        FastOperator::flops(&op),
        plan_flops = FastOperator::flops(plan.as_ref())
    );
    let mut fused = block.clone();
    let t0 = Instant::now();
    op.apply(&mut fused, Direction::Forward, &policy)?;
    let el_fused = t0.elapsed();
    // unfused sequential reference: adjoint → explicit row scale → forward
    let mut want = block.clone();
    let t0 = Instant::now();
    plan.apply(&mut want, Direction::Adjoint, &ExecPolicy::Seq)?;
    let b = want.batch;
    for (i, &hi) in op.response_f32().iter().enumerate() {
        for v in &mut want.data[i * b..(i + 1) * b] {
            *v *= hi;
        }
    }
    plan.apply(&mut want, Direction::Forward, &ExecPolicy::Seq)?;
    let el_ref = t0.elapsed();
    if fused.data != want.data {
        bail!("fused filter diverged from the unfused sequential reference");
    }
    println!(
        "fused apply ({exec}) {el_fused:.2?} vs unfused sequential {el_ref:.2?} — \
         outputs bitwise identical"
    );
    Ok(())
}

/// Parse a `--watch-graph` file: JSON `{"matrix":[..n·n..]}` holding the
/// drifted matrix row-major (same shape as the wire `refactor` op).
fn load_watch_matrix(path: &str) -> crate::Result<Mat> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading --watch-graph {path}: {e}"))?;
    let v = net::Json::parse(&text)?;
    let items = v
        .get("matrix")
        .and_then(|m| m.as_arr())
        .ok_or_else(|| anyhow::anyhow!("{path} needs a row-major \"matrix\" array"))?;
    let mut data = Vec::with_capacity(items.len());
    for item in items {
        match item.as_f64() {
            Some(x) if x.is_finite() => data.push(x),
            _ => bail!("{path}: \"matrix\" must hold finite numbers"),
        }
    }
    let n = (data.len() as f64).sqrt().round() as usize;
    if n == 0 || n * n != data.len() {
        bail!("{path}: \"matrix\" has {} entries, not a square n×n count", data.len());
    }
    Ok(Mat::from_rows(n, n, &data))
}

/// Poll a `--watch-graph` file and enqueue a warm-start refactorization
/// whenever its modification time changes. Jobs are asynchronous: the
/// worker warm-starts from the resident default plan, re-certifies
/// against the drifted matrix, and swaps (or refuses under
/// `--max-error`) while the server keeps serving.
fn spawn_graph_watcher(
    path: String,
    worker: Arc<RefactorWorker>,
    opts: RefactorOptions,
    stop: Arc<std::sync::atomic::AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("fastes-watch-graph".into())
        .spawn(move || {
            let mtime_of = |p: &str| {
                std::fs::metadata(p).and_then(|m| m.modified()).ok()
            };
            let mut last = mtime_of(&path);
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                std::thread::sleep(std::time::Duration::from_millis(200));
                let now = mtime_of(&path);
                if now.is_some() && now != last {
                    last = now;
                    match load_watch_matrix(&path) {
                        Ok(matrix) => {
                            let job = RefactorJob {
                                matrix,
                                from: None,
                                opts: opts.clone(),
                                reply: None,
                            };
                            if !worker.submit(job) {
                                return;
                            }
                        }
                        Err(e) => eprintln!("watch-graph: {e:#}"),
                    }
                }
            }
        })
        .expect("spawn graph watcher")
}

/// `fastes serve` — serve batched GFT requests through the coordinator
/// and report latency/throughput. The operator comes either from an
/// in-process factorization (default: a community-graph Laplacian) or
/// from a saved artifact via `--plan file.fastplan` (no refactorization).
/// `--exec` picks the native execution engine per [`ExecPolicy`]: `pool`
/// (default — fused plan on the shared persistent worker pool), `spawn`
/// (legacy scoped threads per apply) or `seq` (sequential apply).
pub fn serve(a: &Args) -> crate::Result<()> {
    let alpha: usize = a.get("alpha", 2)?;
    let requests: usize = a.get("requests", 2000)?;
    let batch: usize = a.get("batch", 8)?;
    let backend_kind = a.get_str("backend", "native");
    let artifacts = PathBuf::from(a.get_str("artifacts", "artifacts"));
    let plan_path = a.get_str("plan", "");
    let seed: u64 = a.get("seed", 1)?;
    // legacy flag: `--scheduled` was the spawn-per-apply fast path
    let exec = a.get_str("exec", if a.has("scheduled") { "spawn" } else { "pool" });
    let policy = exec_policy_from_args(a, &exec)?;
    if backend_kind != "native" && (a.has("exec") || a.has("scheduled")) {
        bail!("--exec/--scheduled are only supported with --backend native (got {backend_kind})");
    }
    // startup micro-calibration flags (native backend only)
    let autotune_flag = a.get_str("autotune", "");
    let autotune_effort = if autotune_flag.is_empty() {
        None
    } else {
        Some(TuneEffort::parse(&autotune_flag)?)
    };
    let tune_profile_path = a.get_str("tune-profile", "");
    if backend_kind != "native" && (autotune_effort.is_some() || !tune_profile_path.is_empty()) {
        bail!("--autotune/--tune-profile are only supported with --backend native");
    }
    if autotune_effort.is_some() && !tune_profile_path.is_empty() {
        bail!("--tune-profile already fixes the execution config; drop --autotune");
    }
    if matches!(autotune_effort, Some(e) if e != TuneEffort::Off) && a.has("exec") {
        bail!("--autotune supersedes --exec; pass only one");
    }
    if !tune_profile_path.is_empty() && a.has("exec") {
        bail!("--tune-profile supersedes --exec; pass only one");
    }
    // an explicit `--autotune off` must really disable calibration, even
    // for `--exec auto` (which would otherwise resolve at the
    // FASTES_AUTOTUNE effort inside the backend)
    let policy = if matches!(autotune_effort, Some(TuneEffort::Off))
        && matches!(policy, ExecPolicy::Auto)
    {
        ExecPolicy::default()
    } else {
        policy
    };
    if !plan_path.is_empty() && (a.has("n") || a.has("alpha")) {
        bail!(
            "--n/--alpha configure the in-process factorization and conflict with --plan \
             (the artifact fixes the operator and its dimension)"
        );
    }

    let mut rng = Rng64::new(seed);
    let plan: Arc<Plan> = if plan_path.is_empty() {
        let n: usize = a.get("n", 128)?;
        let graph = graphs::community(n, &mut rng);
        let l = graph.laplacian();
        let g = budget(alpha, n);
        println!("factoring community graph n={n} |E|={} with g={g}…", graph.num_edges());
        let f =
            SymFactorizer::new(&l, g, SymOptions { max_sweeps: 1, ..Default::default() }).run();
        println!("factored: rel_err={:.4}", f.relative_error(&l));
        f.plan()
    } else {
        let plan = Plan::load(&plan_path)?;
        println!(
            "loaded {plan_path}: kind={:?} n={} stages={} layers={} superstages={}",
            plan.kind(),
            plan.n(),
            plan.len(),
            plan.stats().layers,
            plan.num_superstages()
        );
        plan
    };
    let chain: GChain = plan
        .as_gchain()
        .ok_or_else(|| anyhow::anyhow!("serve needs a G-chain plan (got a T-chain artifact)"))?
        .clone();
    let n = plan.n();

    // resolve the tuned config up front (worker startup then pays zero
    // sweeps) so the chosen config and score table print before serving
    let tuned_for_backend: Option<(TunedConfig, u64)> = if !tune_profile_path.is_empty() {
        let profile = TuneProfile::load(&tune_profile_path)?;
        profile.ensure_matches(&plan, batch)?;
        println!(
            "tune profile {tune_profile_path}: {} (effort {}, no startup sweep)",
            profile.summary(),
            profile.effort.as_str()
        );
        Some((profile.tuned_config(), 0))
    } else if let Some(effort) = autotune_effort.filter(|&e| e != TuneEffort::Off) {
        let t0 = Instant::now();
        let resolved = autotune::resolve_with(&plan, batch, effort);
        println!(
            "autotune({}): measured {} candidates in {:.2?}",
            effort.as_str(),
            resolved.swept,
            t0.elapsed()
        );
        print!("{}", resolved.tuned.table_text());
        Some(((*resolved.tuned).clone(), resolved.swept as u64))
    } else {
        None
    };
    let policy = match &tuned_for_backend {
        Some((tuned, _)) => tuned.policy.clone(),
        None => policy,
    };

    // `--max-error EPS`: refuse to route to plans whose .fastplan error
    // certificate exceeds EPS (or that carry no certificate at all)
    let max_error = match a.has("max-error") {
        true => {
            let eps: f64 = a.get("max-error", 0.0)?;
            if !(eps.is_finite() && eps > 0.0) {
                bail!("--max-error must be a positive relative error (got {eps})");
            }
            Some(eps)
        }
        false => None,
    };
    let config = ServeConfig { max_batch: batch, max_error, ..Default::default() };

    // `--listen ADDR`: run the hardened TCP front-end (serve/net.rs)
    // instead of the in-process self-driving load loop
    let listen_addr = a.get_str("listen", "");
    if !listen_addr.is_empty() {
        if backend_kind != "native" {
            bail!("--listen currently serves --backend native only");
        }
        let registry_cap: usize = a.get("registry-cap", 64)?;
        let plan_dir = a.get_str("plan-dir", "");
        let search_dirs =
            if plan_dir.is_empty() { Vec::new() } else { vec![PathBuf::from(&plan_dir)] };
        let registry = Arc::new(PlanRegistry::with_search_dirs(registry_cap, search_dirs));
        let default_key = registry.install_default(Arc::clone(&plan));
        // Background warm-start refactorization: wire `refactor` requests
        // and `--watch-graph` file events re-polish the resident chain
        // against a drifted matrix and atomically swap the default plan
        // while in-flight batches drain on the old one.
        let refactor_worker = Arc::new(RefactorWorker::start(Arc::clone(&registry)));
        let watch_graph = a.get_str("watch-graph", "");
        let watch_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let watch_handle = if watch_graph.is_empty() {
            None
        } else {
            let refactor_budget = match a.has("refactor-budget") {
                true => {
                    let eps: f64 = a.get("refactor-budget", 0.0)?;
                    if !(eps.is_finite() && eps > 0.0) {
                        bail!("--refactor-budget must be a positive relative error (got {eps})");
                    }
                    Some(eps)
                }
                false => None,
            };
            println!("watching {watch_graph} for drifted matrices");
            Some(spawn_graph_watcher(
                watch_graph,
                Arc::clone(&refactor_worker),
                RefactorOptions { budget: refactor_budget, max_error, ..Default::default() },
                Arc::clone(&watch_stop),
            ))
        };
        let p = Arc::clone(&plan);
        let pol = policy.clone();
        let tuned = tuned_for_backend;
        let coordinator = Coordinator::start_with_registry(
            move || {
                let backend = match tuned {
                    Some((tc, swept)) => NativeGftBackend::with_tuned(
                        p,
                        TransformDirection::Forward,
                        batch,
                        None,
                        &tc,
                        swept,
                    )?,
                    None => NativeGftBackend::with_policy(
                        p,
                        TransformDirection::Forward,
                        batch,
                        None,
                        pol,
                    )?,
                };
                Ok(Box::new(backend) as Box<dyn Backend>)
            },
            config,
            Some(Arc::clone(&registry)),
        )?;
        let listener = std::net::TcpListener::bind(&listen_addr)
            .map_err(|e| anyhow::anyhow!("binding {listen_addr}: {e}"))?;
        let local = listener.local_addr()?;
        // the smoke harness parses this line for the bound port, so it
        // must hit the pipe before the first request arrives
        println!(
            "listening on {local} (default plan {default_key:016x}, registry capacity {registry_cap})"
        );
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        net::install_termination_handler();
        let shutdown = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let net_opts = net::NetServerOptions {
            refactor: Some(Arc::clone(&refactor_worker)),
            ..Default::default()
        };
        let m = net::serve(listener, coordinator, net_opts, shutdown)?;
        watch_stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = watch_handle {
            let _ = h.join();
        }
        println!("drained: {}", m.line());
        return Ok(());
    }

    let coordinator = match backend_kind.as_str() {
        "native" => {
            let p = Arc::clone(&plan);
            let pol = policy.clone();
            let tuned = tuned_for_backend;
            Coordinator::start(
                move || {
                    let backend = match tuned {
                        Some((tc, swept)) => NativeGftBackend::with_tuned(
                            p,
                            TransformDirection::Forward,
                            batch,
                            None,
                            &tc,
                            swept,
                        )?,
                        None => NativeGftBackend::with_policy(
                            p,
                            TransformDirection::Forward,
                            batch,
                            None,
                            pol,
                        )?,
                    };
                    Ok(Box::new(backend) as Box<dyn Backend>)
                },
                config,
            )?
        }
        "pjrt" => {
            let arrays = chain.to_plan();
            Coordinator::start(
                move || {
                    let store = crate::runtime::ArtifactStore::open(&artifacts)?;
                    Ok(Box::new(PjrtGftBackend::new(
                        store,
                        TransformDirection::Forward,
                        arrays,
                        batch,
                        None,
                    )?) as Box<dyn Backend>)
                },
                config,
            )?
        }
        other => bail!("--backend must be native|pjrt (got {other})"),
    };

    println!(
        "serving {requests} requests (backend={backend_kind}{}, batch={batch})…",
        if backend_kind == "native" {
            format!(
                " exec={}/{}t kernel={}",
                policy.engine(),
                policy.config().map_or(1, |c| c.threads),
                policy.kernel_isa().as_str()
            )
        } else {
            String::new()
        }
    );
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(64);
    let mut checked = 0usize;
    for k in 0..requests {
        let sig: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        pending.push((sig.clone(), coordinator.submit(sig)?));
        if pending.len() >= 64 || k + 1 == requests {
            for (sig, t) in pending.drain(..) {
                let out = t.wait()?;
                // spot-check against the exact f64 path
                if checked < 16 {
                    let mut want: Vec<f64> = sig.iter().map(|&v| v as f64).collect();
                    chain.apply_vec_t(&mut want);
                    for (w, o) in want.iter().zip(out.iter()) {
                        assert!((*w as f32 - o).abs() < 1e-2, "serving mismatch");
                    }
                    checked += 1;
                }
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = coordinator.shutdown();
    println!("throughput: {:.0} req/s over {:.2}s", requests as f64 / elapsed, elapsed);
    println!("metrics: {}", m.line());
    Ok(())
}

/// `fastes tune` — run the execution-engine micro-calibration sweep for
/// an operator (a saved `--plan FILE.fastplan`, or a random G-plan of
/// `--n`/`--alpha`) and print the score table. `--out FILE.fasttune`
/// persists the sweep as a versioned, checksummed JSON profile that
/// `fastes serve --tune-profile` reloads with zero startup sweeps;
/// `--json` prints the same document to stdout.
pub fn tune(a: &Args) -> crate::Result<()> {
    let batch: usize = a.get("batch", 8)?;
    let effort_name = a.get_str("effort", TuneEffort::from_env(TuneEffort::Quick).as_str());
    let effort = TuneEffort::parse(&effort_name)?;
    if effort == TuneEffort::Off {
        bail!("fastes tune needs --effort quick|full (off would measure nothing)");
    }
    let plan_path = a.get_str("plan", "");
    let plan: Arc<Plan> = if plan_path.is_empty() {
        let n: usize = a.get("n", 64)?;
        let alpha: usize = a.get("alpha", 2)?;
        let seed: u64 = a.get("seed", 1)?;
        let g = budget(alpha, n);
        let mut rng = Rng64::new(seed);
        println!(
            "tuning a random G-plan n={n} g={g} seed={seed} \
             (pass --plan FILE.fastplan to tune a saved operator)"
        );
        Plan::from(random_gplan(n, g, &mut rng)).build()
    } else {
        let plan = Plan::load(&plan_path)?;
        println!(
            "tuning {plan_path}: kind={:?} n={} stages={} layers={}",
            plan.kind(),
            plan.n(),
            plan.len(),
            plan.stats().layers
        );
        plan
    };
    let t0 = Instant::now();
    let tuned = autotune::tune_plan(&plan, batch, effort, &mut WallTimer);
    println!(
        "sweep: {} candidates, effort={}, batch={batch}, elapsed={:.2?}",
        tuned.score_table.len(),
        effort.as_str(),
        t0.elapsed()
    );
    print!("{}", tuned.table_text());
    println!("chosen: {}", tuned.summary());
    let profile = TuneProfile::new(&plan, batch, &tuned);
    if a.has("json") {
        print!("{}", profile.to_json());
    }
    let out = a.get_str("out", "");
    if !out.is_empty() {
        profile.save(&out)?;
        println!(
            "wrote {out} (plan checksum {:016x}, batch bucket {}) — reload with \
             `fastes serve --tune-profile {out}`",
            profile.plan_checksum, profile.batch_bucket
        );
    }
    Ok(())
}

/// `fastes kernels` — report the SIMD kernel dispatch of this host:
/// detected best ISA, resolved process default (env/CLI overrides
/// applied) and every available kernel. CI asserts the native-runner
/// default is non-scalar on x86_64 through this command.
pub fn kernels(a: &Args) -> crate::Result<()> {
    // honour --kernel so `fastes kernels --kernel scalar` previews a pin
    let _ = kernel_from_args(a)?;
    println!("arch: {}", std::env::consts::ARCH);
    println!("detected: {}", KernelIsa::detect().as_str());
    println!("default: {}", simd::default_kernel().as_str());
    println!(
        "available: {}",
        KernelIsa::available().iter().map(|k| k.as_str()).collect::<Vec<_>>().join(" ")
    );
    println!(
        "override: FASTES_KERNEL={}",
        std::env::var("FASTES_KERNEL").unwrap_or_else(|_| "(unset)".into())
    );
    println!("lane widths: scalar=1 neon=4 avx2=8 avx512=16 (f32 lanes)");
    println!(
        "bitwise guarantee: every kernel is bit-identical to scalar (no FMA, no reassociation)"
    );
    Ok(())
}

/// `fastes eigen` — symmetric eigensolver smoke test.
pub fn eigen(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 256)?;
    let seed: u64 = a.get("seed", 1)?;
    let mut rng = Rng64::new(seed);
    let x = Mat::randn(n, n, &mut rng);
    let s = &x + &x.transpose();
    let t0 = Instant::now();
    let e = eigh(&s);
    let rel = e.reconstruct().fro_dist_sq(&s) / s.fro_norm_sq();
    println!(
        "eigh n={n}: reconstruction rel²={rel:.3e}, λ_max={:.4}, λ_min={:.4}, elapsed={:.2?}",
        e.values[0],
        e.values[n - 1],
        t0.elapsed()
    );
    Ok(())
}

/// `fastes schedule` — compile a butterfly chain into conflict-free
/// layers + fused superstages, report the schedule shape (layer count /
/// depth / width / superstages) and time the sequential vs spawn vs
/// pooled [`ExecPolicy`] engines through [`FastOperator::apply`].
pub fn schedule(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 512)?;
    let alpha: usize = a.get("alpha", 2)?;
    let batch: usize = a.get("batch", 32)?;
    let seed: u64 = a.get("seed", 1)?;
    let seq = ExecPolicy::Seq;
    let spawn = exec_policy_from_args(a, "spawn")?;
    let pool = exec_policy_from_args(a, "pool")?;
    let threads = pool.config().map_or(1, |c| c.threads);
    let g = budget(alpha, n);
    let mut rng = Rng64::new(seed);

    let gplan = Plan::from(random_gplan(n, g, &mut rng)).build();
    let tplan = Plan::from(random_tplan(n, g, &mut rng)).build();
    for (label, plan) in [("G-chain", &gplan), ("T-chain", &tplan)] {
        let stats = plan.stats();
        println!(
            "{label}: n={n} stages={} layers={} depth-reduction={:.1}x max-width={} superstages={}",
            stats.stages,
            stats.layers,
            stats.mean_width,
            stats.max_width,
            plan.num_superstages()
        );
    }

    // timing: the three engines over the same plan, same direction
    let signals: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..n).map(|_| rng.randn() as f32).collect())
        .collect();
    let mut results = Vec::new();
    for (label, policy) in [
        ("sequential apply".to_string(), &seq),
        (format!("spawn apply ({threads} threads)"), &spawn),
        (format!("pooled apply ({threads} threads)"), &pool),
    ] {
        let mut block = SignalBlock::from_signals(&signals)?;
        let t = crate::bench_util::bench(&label, 5, 0.05, || {
            gplan.apply(&mut block, Direction::Forward, policy).expect("dims match");
            block.data[0]
        });
        println!("{}", t.line());
        results.push(t);
    }
    println!(
        "batch={batch}: spawn/{threads}t {:.2}x, pooled/{threads}t {:.2}x vs sequential",
        results[0].min_s / results[1].min_s,
        results[0].min_s / results[2].min_s
    );
    Ok(())
}

/// `fastes bench` — machine-readable apply benchmark: ns/stage and GB/s
/// for sequential vs spawn-per-apply vs pooled execution of
/// level-scheduled G-plans at fixed seeds. `--json` writes the results to
/// `BENCH_apply.json` (or `--out PATH`) so the perf trajectory of the
/// apply hot path is tracked in a machine-readable artifact.
pub fn bench(a: &Args) -> crate::Result<()> {
    if a.has("factor") {
        return bench_factor(a);
    }
    if a.has("filter") {
        return bench_filter(a);
    }
    if a.has("refactor") {
        return bench_refactor(a);
    }
    let sizes = a.get_list("sizes", &[256, 512, 1024])?;
    let batch: usize = a.get("batch", 64)?;
    let alpha: usize = a.get("alpha", 2)?;
    let seed: u64 = a.get("seed", 1)?;
    // --autotune off|quick|full: also run the auto-tuned config per size
    // and stamp it into BENCH_apply.json (the calibrated-snapshot flow)
    let tune_effort = TuneEffort::parse(&a.get_str("autotune", "off"))?;
    let seq = ExecPolicy::Seq;
    // each engine gets its own tunable defaults under the shared flag
    // overrides, so `--min-work` really reaches both parallel modes
    let spawn = exec_policy_from_args(a, "spawn")?;
    let pool = exec_policy_from_args(a, "pool")?;
    let cfg = pool.config().expect("pool policy carries a config").clone();
    let spawn_cfg = spawn.config().expect("spawn policy carries a config").clone();
    let threads = cfg.threads;
    let kernel_isa = cfg.kernel_isa();
    println!("kernel ISA: {} (detected: {})", kernel_isa.as_str(), KernelIsa::detect().as_str());
    let mut entries = Vec::new();

    for &n in &sizes {
        if n < 2 {
            bail!("--sizes entries must be ≥ 2 (got {n})");
        }
        let g = budget(alpha, n);
        // deterministic per-size seed so sizes can be re-run independently
        let mut rng = Rng64::new(seed ^ ((n as u64) << 20));
        let plan = Plan::from(random_gplan(n, g, &mut rng)).build();
        let st = plan.stats();
        let signals: Vec<Vec<f32>> = (0..batch)
            .map(|_| (0..n).map(|_| rng.randn() as f32).collect())
            .collect();
        // nominal memory traffic per apply: every (paired) stage streams
        // two batch-length f32 rows in and out → 16 B per stage-column
        let bytes = 16.0 * g as f64 * batch as f64;

        let mut timed = Vec::new();
        for (label, policy) in [
            (format!("n={n} sequential"), &seq),
            (format!("n={n} spawn/{threads}t"), &spawn),
            (format!("n={n} pooled/{threads}t"), &pool),
        ] {
            let mut blk = SignalBlock::from_signals(&signals)?;
            let t = crate::bench_util::bench(&label, 5, 0.05, || {
                plan.apply(&mut blk, Direction::Forward, policy).expect("dims match");
                blk.data[0]
            });
            println!("{}", t.line());
            timed.push(t);
        }
        let (t_seq, t_spawn, t_pool) = (&timed[0], &timed[1], &timed[2]);
        println!(
            "n={n} g={g} batch={batch}: pooled {:.2}x vs sequential, {:.2}x vs spawn",
            t_seq.min_s / t_pool.min_s,
            t_spawn.min_s / t_pool.min_s
        );
        // auto-tuned mode: resolve (cached per plan/batch bucket), time
        // the winner, and stamp its config + measurement into the JSON
        let tuned_json = if tune_effort == TuneEffort::Off {
            String::new()
        } else {
            let resolved = autotune::resolve_with(&plan, batch, tune_effort);
            let tuned_policy = resolved.tuned.policy.clone();
            let mut blk = SignalBlock::from_signals(&signals)?;
            let t = crate::bench_util::bench(
                &format!("n={n} tuned[{}]", resolved.tuned.summary()),
                5,
                0.05,
                || {
                    plan.apply(&mut blk, Direction::Forward, &tuned_policy).expect("dims match");
                    blk.data[0]
                },
            );
            println!("{}", t.line());
            let (t_threads, t_tile, t_min_work, t_kernel) = match tuned_policy.config() {
                Some(c) => (
                    c.threads,
                    c.tile_cols,
                    c.min_work,
                    c.kernel.map_or("auto", |k| k.as_str()).to_string(),
                ),
                None => (1, 0, 0, "auto".to_string()),
            };
            format!(
                ", \"tuned\": {{\"engine\": \"{}\", \"threads\": {t_threads}, \
                 \"tile_cols\": {t_tile}, \"min_work\": {t_min_work}, \
                 \"kernel\": \"{t_kernel}\", \"sweeps\": {}, \"ns_per_stage\": {:.4}}}",
                tuned_policy.engine(),
                resolved.swept,
                t.min_s * 1e9 / g as f64
            )
        };
        let mode = |t: &crate::bench_util::BenchResult| {
            format!(
                "{{\"ns_per_stage\": {:.4}, \"gb_per_s\": {:.4}, \"min_s\": {:.9}}}",
                t.min_s * 1e9 / g as f64,
                bytes / t.min_s / 1e9,
                t.min_s
            )
        };
        entries.push(format!(
            "    {{\"n\": {n}, \"stages\": {g}, \"layers\": {}, \"max_width\": {}, \
             \"superstages\": {}, \"sequential\": {}, \"spawn\": {}, \"pooled\": {}, \
             \"pooled_speedup_vs_sequential\": {:.4}, \"pooled_speedup_vs_spawn\": {:.4}{}}}",
            st.layers,
            st.max_width,
            plan.num_superstages(),
            mode(t_seq),
            mode(t_spawn),
            mode(t_pool),
            t_seq.min_s / t_pool.min_s,
            t_spawn.min_s / t_pool.min_s,
            tuned_json
        ));
    }

    if a.has("json") {
        let out_path = a.get_str("out", "BENCH_apply.json");
        // `sequential_engine` documents the baseline: since the
        // FastOperator unification the "sequential" column times the
        // fused single-pass Seq engine, not the old per-stage apply —
        // cross-version comparisons of *_vs_sequential must check this
        // `kernel_isa` records which SIMD kernel the run dispatched to —
        // numbers from different kernels are comparable in correctness
        // (bitwise-identical results) but not in speed
        // `autotune` records whether (and at what effort) the per-size
        // `tuned` objects below were calibrated — "off" means no tuned
        // mode was run and the rows carry no tuned field
        let json = format!(
            "{{\n  \"bench\": \"apply\",\n  \"sequential_engine\": \"seq-fused\",\n  \
             \"kernel_isa\": \"{}\",\n  \"autotune\": \"{}\",\n  \
             \"seed\": {seed},\n  \"alpha\": {alpha},\n  \
             \"batch\": {batch},\n  \"threads\": {threads},\n  \"tile_cols\": {},\n  \
             \"min_work\": {},\n  \"spawn_min_work\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            kernel_isa.as_str(),
            tune_effort.as_str(),
            cfg.tile_cols,
            cfg.min_work,
            spawn_cfg.min_work,
            entries.join(",\n")
        );
        std::fs::write(&out_path, json)
            .map_err(|e| anyhow::anyhow!("cannot write {out_path}: {e}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// `fastes bench --filter` — fused-vs-unfused spectral filter benchmark.
/// Per size, times the fused single-pass [`FilterOp`] against the
/// unfused adjoint → row-scale → forward route (same plan, same heat
/// response, bitwise-identical outputs — asserted before timing), both
/// sequential and pooled. `--json` stamps the ns/stage rows into
/// `BENCH_apply.json` (or `--out PATH`) as a `"bench": "filter"`
/// document, so the fusion win is tracked alongside the plain apply
/// trajectory.
fn bench_filter(a: &Args) -> crate::Result<()> {
    let sizes = a.get_list("sizes", &[256, 512, 1024])?;
    let batch: usize = a.get("batch", 64)?;
    let alpha: usize = a.get("alpha", 2)?;
    let seed: u64 = a.get("seed", 1)?;
    let pool = exec_policy_from_args(a, "pool")?;
    let cfg = pool.config().expect("pool policy carries a config").clone();
    let threads = cfg.threads;
    let kernel_isa = cfg.kernel_isa();
    println!("kernel ISA: {} (detected: {})", kernel_isa.as_str(), KernelIsa::detect().as_str());
    let mut entries = Vec::new();
    for &n in &sizes {
        if n < 2 {
            bail!("--sizes entries must be ≥ 2 (got {n})");
        }
        let g = budget(alpha, n);
        // deterministic per-size seed so sizes can be re-run independently
        let mut rng = Rng64::new(seed ^ ((n as u64) << 20));
        let spectrum: Vec<f64> = (0..n).map(|_| rng.randn().abs() * 2.0).collect();
        let plan = Plan::from(random_gplan(n, g, &mut rng)).spectrum(spectrum).build();
        let op = FilterOp::from_kernel(Arc::clone(&plan), &SpectralKernel::Heat { t: 0.5 })?;
        let h32: Vec<f32> = op.response_f32().to_vec();
        let signals: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
        // the unfused reference route, shared by the check and the timings
        let unfused = |blk: &mut SignalBlock, policy: &ExecPolicy| {
            plan.apply(blk, Direction::Adjoint, policy).expect("dims match");
            let b = blk.batch;
            for (i, &hi) in h32.iter().enumerate() {
                for v in &mut blk.data[i * b..(i + 1) * b] {
                    *v *= hi;
                }
            }
            plan.apply(blk, Direction::Forward, policy).expect("dims match");
        };
        // bitwise identity first — the speedup rows only mean anything if
        // both routes compute the same answer
        let mut fused_blk = SignalBlock::from_signals(&signals)?;
        op.apply(&mut fused_blk, Direction::Forward, &ExecPolicy::Seq)?;
        let mut ref_blk = SignalBlock::from_signals(&signals)?;
        unfused(&mut ref_blk, &ExecPolicy::Seq);
        if fused_blk.data != ref_blk.data {
            bail!("fused filter diverged from the unfused reference at n={n}");
        }

        // a filter traverses every stage twice (reverse + forward)
        let stages2 = 2 * g;
        let mut timed = Vec::new();
        for (label, is_fused, policy) in [
            (format!("n={n} fused seq"), true, &ExecPolicy::Seq),
            (format!("n={n} unfused seq"), false, &ExecPolicy::Seq),
            (format!("n={n} fused pooled/{threads}t"), true, &pool),
            (format!("n={n} unfused pooled/{threads}t"), false, &pool),
        ] {
            let mut blk = SignalBlock::from_signals(&signals)?;
            let t = crate::bench_util::bench(&label, 5, 0.05, || {
                if is_fused {
                    op.apply(&mut blk, Direction::Forward, policy).expect("dims match");
                } else {
                    unfused(&mut blk, policy);
                }
                blk.data[0]
            });
            println!("{}", t.line());
            timed.push(t);
        }
        println!(
            "n={n} g={g} batch={batch}: fused {:.2}x vs unfused (seq), {:.2}x (pooled/{threads}t)",
            timed[1].min_s / timed[0].min_s,
            timed[3].min_s / timed[2].min_s
        );
        let mode = |t: &crate::bench_util::BenchResult| {
            format!(
                "{{\"ns_per_stage\": {:.4}, \"min_s\": {:.9}}}",
                t.min_s * 1e9 / stages2 as f64,
                t.min_s
            )
        };
        entries.push(format!(
            "    {{\"n\": {n}, \"stages\": {g}, \"traversed_stages\": {stages2}, \
             \"fused_seq\": {}, \"unfused_seq\": {}, \"fused_pooled\": {}, \
             \"unfused_pooled\": {}, \"fused_speedup_seq\": {:.4}, \
             \"fused_speedup_pooled\": {:.4}}}",
            mode(&timed[0]),
            mode(&timed[1]),
            mode(&timed[2]),
            mode(&timed[3]),
            timed[1].min_s / timed[0].min_s,
            timed[3].min_s / timed[2].min_s
        ));
    }
    if a.has("json") {
        let out_path = a.get_str("out", "BENCH_apply.json");
        let json = format!(
            "{{\n  \"bench\": \"filter\",\n  \"kernel_isa\": \"{}\",\n  \"seed\": {seed},\n  \
             \"alpha\": {alpha},\n  \"batch\": {batch},\n  \"threads\": {threads},\n  \
             \"response\": \"heat(0.5)\",\n  \"results\": [\n{}\n  ]\n}}\n",
            kernel_isa.as_str(),
            entries.join(",\n")
        );
        std::fs::write(&out_path, json)
            .map_err(|e| anyhow::anyhow!("cannot write {out_path}: {e}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// One `BENCH_factor.json` result row (also printed to stdout).
fn bench_factor_row(
    kind: &str,
    n: usize,
    g: usize,
    threads: usize,
    steps: usize,
    secs: f64,
    rel: f64,
) -> String {
    let steps = steps.max(1);
    let ns = secs * 1e9 / steps as f64;
    let sps = steps as f64 / secs.max(1e-12);
    println!(
        "{kind} n={n} g={g} threads={threads}: {steps} steps, {ns:.0} ns/step, \
         {sps:.0} steps/s, rel_err={rel:.4}"
    );
    format!(
        "    {{\"kind\": \"{kind}\", \"n\": {n}, \"budget\": {g}, \"threads\": {threads}, \
         \"steps\": {steps}, \"total_s\": {secs:.6}, \"ns_per_step\": {ns:.1}, \
         \"steps_per_sec\": {sps:.1}, \"rel_err\": {rel:.6}}}"
    )
}

/// `fastes bench --factor` — machine-readable factorization benchmark:
/// per-(kind, n, threads) step timings for the sym and gen factorizers
/// at fixed seeds, serial vs pooled. A progress step is one greedy init
/// factor placed or one polishing sweep completed; the thread count
/// never changes the produced chain, only wall-clock. `--json` writes
/// `BENCH_factor.json` (or `--out PATH`) so the perf trajectory of plan
/// *construction* is tracked like `BENCH_apply.json` tracks apply.
fn bench_factor(a: &Args) -> crate::Result<()> {
    let sizes = a.get_list("sizes", &[48, 64])?;
    let alpha: usize = a.get("alpha", 2)?;
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 1)?;
    let exec = factor_exec_from_args(a)?;
    let mut thread_counts = vec![1usize];
    if exec.threads > 1 {
        thread_counts.push(exec.threads);
    }
    let mut entries = Vec::new();
    for &n in &sizes {
        if n < 2 {
            bail!("--sizes entries must be ≥ 2 (got {n})");
        }
        let g = budget(alpha, n);
        // deterministic per-size seed so sizes can be re-run independently
        let mut rng = Rng64::new(seed ^ ((n as u64) << 20));
        let x = Mat::randn(n, n, &mut rng);
        let s = &x + &x.transpose();
        for &threads in &thread_counts {
            // min_work 0 forces the parallel paths even at bench sizes;
            // threads == 1 is the true sequential reference
            let run_exec = match threads {
                1 => FactorExec::serial(),
                t => FactorExec { threads: t, min_work: 0 },
            };
            let t0 = Instant::now();
            let f = SymFactorizer::new(
                &s,
                g,
                SymOptions { max_sweeps: sweeps, exec: run_exec, ..Default::default() },
            )
            .run();
            let el = t0.elapsed().as_secs_f64();
            let steps = f.chain.len() + f.sweeps_run;
            entries.push(bench_factor_row("sym", n, g, threads, steps, el, f.relative_error(&s)));
            let t0 = Instant::now();
            let f = GeneralFactorizer::new(
                &x,
                g,
                GeneralOptions { max_sweeps: sweeps, exec: run_exec, ..Default::default() },
            )
            .run();
            let el = t0.elapsed().as_secs_f64();
            let steps = f.chain.len() + f.sweeps_run;
            entries.push(bench_factor_row("gen", n, g, threads, steps, el, f.relative_error(&x)));
        }
    }
    if a.has("json") {
        let out_path = a.get_str("out", "BENCH_factor.json");
        let threads_json = thread_counts
            .iter()
            .map(ToString::to_string)
            .collect::<Vec<_>>()
            .join(", ");
        let json = format!(
            "{{\n  \"bench\": \"factor\",\n  \"seed\": {seed},\n  \"alpha\": {alpha},\n  \
             \"sweeps\": {sweeps},\n  \"threads\": [{threads_json}],\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        std::fs::write(&out_path, json)
            .map_err(|e| anyhow::anyhow!("cannot write {out_path}: {e}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// One `BENCH_refactor.json` start-mode object (`"cold"` / `"warm"`).
fn bench_refactor_mode(
    g: usize,
    rel: f64,
    stats: &crate::factor::BudgetRunStats,
    secs: f64,
) -> String {
    format!(
        "{{\"g\": {g}, \"sweeps\": {}, \"growth_rounds\": {}, \"factors_added\": {}, \
         \"rel_err\": {rel:.6e}, \"total_s\": {secs:.6}}}",
        stats.total_sweeps, stats.growth_rounds, stats.factors_added
    )
}

/// `fastes bench --refactor` — warm-vs-cold iterations-to-budget on
/// drifted graphs. Per (family, n): cold-factor the base Laplacian to
/// `--error-budget` (that run's chain is the donor), apply `--drift K`
/// deterministic edge updates, then reach the same budget on the
/// drifted Laplacian both cold (from scratch) and warm (donor chain
/// re-polished via [`SymFactorizer::run_to_budget_warm`]). The warm row
/// should hit budget in measurably fewer sweeps; `--json` writes the
/// rows to `BENCH_refactor.json` (or `--out PATH`) so the warm-start
/// advantage is tracked like the other bench artifacts.
fn bench_refactor(a: &Args) -> crate::Result<()> {
    let sizes = a.get_list("sizes", &[48, 64])?;
    let alpha: usize = a.get("alpha", 2)?;
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let drift_steps: usize = a.get("drift", 6)?;
    let eps: f64 = a.get("error-budget", 0.25)?;
    if !(eps.is_finite() && eps > 0.0) {
        bail!("--error-budget must be a positive relative error (got {eps})");
    }
    let fams_raw = a.get_str("families", "community,er");
    let families: Vec<String> = fams_raw
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if families.is_empty() {
        bail!("--families must name at least one graph family (got '{fams_raw}')");
    }
    let exec = factor_exec_from_args(a)?;
    let sym_opts = SymOptions { max_sweeps: sweeps, exec, ..Default::default() };
    let mut entries = Vec::new();
    for (fi, family) in families.iter().enumerate() {
        for &n in &sizes {
            if n < 2 {
                bail!("--sizes entries must be ≥ 2 (got {n})");
            }
            // per-(family, size) deterministic stream so rows can be
            // re-run independently
            let mut rng = Rng64::new(seed ^ ((fi as u64 + 1) << 32) ^ ((n as u64) << 20));
            let mut graph = match family.as_str() {
                "community" => graphs::community(n, &mut rng),
                "er" | "erdos-renyi" => graphs::erdos_renyi(n, 0.3, &mut rng),
                "sensor" => graphs::sensor(n, &mut rng),
                other => bail!("--families supports community|er|sensor (got {other})"),
            };
            let g_start = budget(alpha, n);
            let g_max = (n * (n - 1) / 2).max(g_start);
            // donor: cold run against the pre-drift Laplacian
            let l0 = graph.laplacian();
            let (donor, _, _) =
                SymFactorizer::run_to_budget_stats(&l0, eps, g_start, g_max, sym_opts.clone());
            let updates = graphs::drift(&mut graph, drift_steps, seed ^ ((n as u64) << 8));
            let l1 = graph.laplacian();
            // cold: same budgeted procedure from scratch on the drifted
            // matrix — the baseline the warm start must beat
            let t0 = Instant::now();
            let (cf, ccert, cstats) =
                SymFactorizer::run_to_budget_stats(&l1, eps, g_start, g_max, sym_opts.clone());
            let cold_s = t0.elapsed().as_secs_f64();
            // warm: donor chain re-polished against the drifted matrix
            let t0 = Instant::now();
            let (wf, wcert, wstats) = SymFactorizer::run_to_budget_warm(
                &l1,
                donor.chain.clone(),
                eps,
                g_max,
                sym_opts.clone(),
            );
            let warm_s = t0.elapsed().as_secs_f64();
            let ratio = wstats.total_sweeps as f64 / cstats.total_sweeps.max(1) as f64;
            println!(
                "{family} n={n} drift={} budget={eps:.3e}: cold g={} sweeps={} \
                 rel={:.4} {cold_s:.3}s | warm g={} sweeps={} rel={:.4} {warm_s:.3}s \
                 ({ratio:.2}x sweeps)",
                updates.len(),
                cf.chain.len(),
                cstats.total_sweeps,
                ccert.rel_err,
                wf.chain.len(),
                wstats.total_sweeps,
                wcert.rel_err
            );
            entries.push(format!(
                "    {{\"family\": \"{family}\", \"n\": {n}, \"budget\": {eps:.6e}, \
                 \"drift_steps\": {}, \"donor_g\": {}, \"cold\": {}, \"warm\": {}, \
                 \"warm_vs_cold_sweeps\": {ratio:.4}}}",
                updates.len(),
                donor.chain.len(),
                bench_refactor_mode(cf.chain.len(), ccert.rel_err, &cstats, cold_s),
                bench_refactor_mode(wf.chain.len(), wcert.rel_err, &wstats, warm_s)
            ));
        }
    }
    if a.has("json") {
        let out_path = a.get_str("out", "BENCH_refactor.json");
        let fams_json =
            families.iter().map(|f| format!("\"{f}\"")).collect::<Vec<_>>().join(", ");
        let json = format!(
            "{{\n  \"bench\": \"refactor\",\n  \"seed\": {seed},\n  \"alpha\": {alpha},\n  \
             \"sweeps\": {sweeps},\n  \"drift\": {drift_steps},\n  \
             \"error_budget\": {eps:.6e},\n  \"families\": [{fams_json}],\n  \
             \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        std::fs::write(&out_path, json)
            .map_err(|e| anyhow::anyhow!("cannot write {out_path}: {e}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// `fastes bench-apply` — quick butterfly vs dense apply timing.
pub fn bench_apply(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 1024)?;
    let alpha: usize = a.get("alpha", 2)?;
    let g = budget(alpha, n);
    let mut rng = Rng64::new(3);
    let plan = Plan::from(random_gplan(n, g, &mut rng)).build();
    let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
    let dense: Vec<f32> = (0..n * n).map(|_| rng.randn() as f32).collect();
    let mut y = vec![0f32; n];
    let td = crate::bench_util::bench("dense gemv", 7, 0.05, || {
        for (r, yr) in y.iter_mut().enumerate() {
            let row = &dense[r * n..(r + 1) * n];
            let mut acc = 0f32;
            for (u, v) in row.iter().zip(x.iter()) {
                acc += u * v;
            }
            *yr = acc;
        }
        y[0]
    });
    let mut block = SignalBlock::from_signals(&[x.clone()])?;
    let tb = crate::bench_util::bench("butterfly apply", 7, 0.05, || {
        plan.apply(&mut block, Direction::Forward, &ExecPolicy::Seq).expect("dims match");
        block.data[0]
    });
    println!("{}", td.line());
    println!("{}", tb.line());
    println!(
        "n={n} g={g}: flop ratio {:.2}, measured speedup {:.2}",
        (2 * n * n) as f64 / (6 * g) as f64,
        td.min_s / tb.min_s
    );
    Ok(())
}

/// The Lemma-1 spectrum `s̄ = diag(ŪᵀSŪ)` of a chain against a symmetric
/// matrix — the diagonal [`certify_g`] measures the residual against
/// (same conjugation order as the certificate itself).
fn lemma1_spectrum(chain: &GChain, s: &Mat) -> Vec<f64> {
    let mut w = s.clone();
    for t in chain.transforms.iter().rev() {
        t.conjugate_t(&mut w);
    }
    (0..chain.n).map(|i| w[(i, i)]).collect()
}

/// Laplacian of a named bakeoff graph family. Masked-grid may round the
/// vertex count up to the enclosing grid (masked cells stay isolated).
fn bakeoff_graph(family: &str, n: usize, rng: &mut Rng64) -> crate::Result<Mat> {
    Ok(match family {
        "community" => graphs::community(n, rng).laplacian(),
        "er" | "erdos-renyi" => graphs::erdos_renyi(n, 0.3, rng).laplacian(),
        "masked-grid" => {
            let rows = ((n as f64).sqrt().round() as usize).max(1);
            let cols = (n + rows - 1) / rows;
            let mask: Vec<bool> =
                (0..rows * cols).map(|i| i < n && !rng.bernoulli(0.2)).collect();
            graphs::masked_grid(rows, cols, &mask).laplacian()
        }
        other => bail!("bakeoff: unknown family '{other}' (er|community|masked-grid)"),
    })
}

/// Print one bakeoff frontier point and return it as a JSON results row.
fn bakeoff_row(family: &str, method: &str, n: usize, g: usize, flops: usize, rel: f64) -> String {
    println!("{family:<12} {method:<14} n={n:4} g={g:5} flops={flops:8} rel_err={rel:.4e}");
    format!(
        "    {{ \"family\": \"{family}\", \"method\": \"{method}\", \"n\": {n}, \"g\": {g}, \
         \"flops\": {flops}, \"rel_err\": {rel:.6e} }}"
    )
}

/// `fastes bakeoff` — our Givens factorizer against the baseline methods
/// on the flops-vs-error frontier, per graph family. Every chain method
/// is scored with the same certificate metric
/// (`‖S − Ū diag(s̄) Ūᵀ‖_F / ‖S‖_F`, [`certify_g`]); the low-rank
/// baseline is scored at the flop-matched rank `r = 3g/n` (a rank-`r`
/// apply costs `2rn` flops vs 6 per G-transform). `--json` writes
/// `BENCH_error.json` (override with `--out`).
pub fn bakeoff(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 64)?;
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let alphas = a.get_list("alphas", &[1, 2, 4])?;
    if alphas.is_empty() {
        bail!("--alphas must name at least one budget multiplier");
    }
    let fams_raw = a.get_str("families", "er,community,masked-grid");
    let families: Vec<String> = fams_raw
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if families.len() < 2 {
        bail!("bakeoff needs at least two graph families (got '{fams_raw}')");
    }
    let mut entries: Vec<String> = Vec::new();
    for (fi, family) in families.iter().enumerate() {
        // per-family deterministic stream, stable under --seed
        let mut rng = Rng64::new(seed ^ ((fi as u64 + 1) << 32));
        let l = bakeoff_graph(family, n, &mut rng)?;
        let n_eff = l.rows();
        let norm_sq = l.fro_norm_sq();
        // the direct-U baseline factors the *known* eigenspace
        let u = eigh(&l).vectors;
        let ones = vec![1.0; n_eff];
        for &alpha in &alphas {
            let g = budget(alpha, n_eff);
            let f = SymFactorizer::new(
                &l,
                g,
                SymOptions { max_sweeps: sweeps, ..Default::default() },
            )
            .run();
            let cert = f.certificate(&l);
            entries.push(bakeoff_row(
                family,
                "givens",
                n_eff,
                f.chain.len(),
                f.chain.flops(),
                cert.rel_err,
            ));
            let r = greedy_givens(&l, g);
            let c = certify_g(&r.chain, &l, &r.spectrum, &[]);
            entries.push(bakeoff_row(
                family,
                "greedy-givens",
                n_eff,
                r.chain.len(),
                r.chain.flops(),
                c.rel_err,
            ));
            let r = truncated_jacobi(&l, g);
            let c = certify_g(&r.chain, &l, &r.spectrum, &[]);
            entries.push(bakeoff_row(
                family,
                "jacobi",
                n_eff,
                r.chain.len(),
                r.chain.flops(),
                c.rel_err,
            ));
            let d = factor_orthonormal(&u, &ones, g);
            let spec = lemma1_spectrum(&d.chain, &l);
            let c = certify_g(&d.chain, &l, &spec, &[]);
            entries.push(bakeoff_row(
                family,
                "direct-u",
                n_eff,
                d.chain.len(),
                d.chain.flops(),
                c.rel_err,
            ));
            // flop-matched rank: 2rn ≈ 6g per apply ("g" records the rank)
            let rank = ((6 * g) / (2 * n_eff)).clamp(1, n_eff);
            let rel = (lowrank_error_symmetric(&l, rank) / norm_sq).sqrt();
            entries.push(bakeoff_row(family, "lowrank", n_eff, rank, 2 * rank * n_eff, rel));
        }
    }
    if a.has("json") {
        let out_path = a.get_str("out", "BENCH_error.json");
        let alphas_json =
            alphas.iter().map(ToString::to_string).collect::<Vec<_>>().join(", ");
        let fams_json =
            families.iter().map(|f| format!("\"{f}\"")).collect::<Vec<_>>().join(", ");
        let json = format!(
            "{{\n  \"bench\": \"error\",\n  \"n\": {n},\n  \"seed\": {seed},\n  \
             \"sweeps\": {sweeps},\n  \"alphas\": [{alphas_json}],\n  \
             \"families\": [{fams_json}],\n  \"results\": [\n{}\n  ]\n}}\n",
            entries.join(",\n")
        );
        std::fs::write(&out_path, json)
            .map_err(|e| anyhow::anyhow!("cannot write {out_path}: {e}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}
