//! Non-figure CLI commands: factor / gft / serve / eigen / bench-apply.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::bail;

use super::figures::{budget, random_gplan, random_tplan};
use super::Args;
use crate::factor::{GeneralFactorizer, GeneralOptions, SymFactorizer, SymOptions};
use crate::graphs::{self, RealWorldGraph};
use crate::linalg::{eigh, Mat, Rng64};
use crate::serve::{
    Backend, Coordinator, NativeGftBackend, PjrtGftBackend, ServeConfig, TransformDirection,
};
use crate::transforms::{default_threads, ChainKind, CompiledPlan, SignalBlock};

/// `fastes factor` — factor a random matrix and report accuracy/time.
pub fn factor(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 128)?;
    let g: usize = a.get("budget", budget(2, n))?;
    let seed: u64 = a.get("seed", 1)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let kind = a.get_str("kind", "sym");
    let mut rng = Rng64::new(seed);
    let x = Mat::randn(n, n, &mut rng);
    let t0 = Instant::now();
    match kind.as_str() {
        "sym" | "psd" => {
            let s = if kind == "psd" { x.matmul(&x.transpose()) } else { &x + &x.transpose() };
            let opts = SymOptions {
                max_sweeps: sweeps,
                full_update: a.has("full-update"),
                ..Default::default()
            };
            let f = SymFactorizer::new(&s, g, opts).run();
            println!(
                "sym n={n} g={g} init_rel={:.4} final_rel={:.4} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                (f.init_objective / s.fro_norm_sq()).sqrt(),
                f.relative_error(&s),
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
        }
        "gen" => {
            let opts = GeneralOptions {
                max_sweeps: sweeps,
                full_update: a.has("full-update"),
                ..Default::default()
            };
            let f = GeneralFactorizer::new(&x, g, opts).run();
            println!(
                "gen n={n} m={g} init_rel={:.4} final_rel={:.4} sweeps={} flops/apply={} dense={} elapsed={:.2?}",
                (f.init_objective / x.fro_norm_sq()).sqrt(),
                f.relative_error(&x),
                f.sweeps_run,
                f.chain.flops(),
                2 * n * n,
                t0.elapsed()
            );
        }
        other => bail!("--kind must be sym|psd|gen (got {other})"),
    }
    Ok(())
}

fn build_graph(a: &Args, rng: &mut Rng64) -> crate::Result<graphs::Graph> {
    let n: usize = a.get("n", 128)?;
    let name = a.get_str("graph", "community");
    let scale: f64 = a.get("scale", 0.25)?;
    Ok(match name.as_str() {
        "community" => graphs::community(n, rng),
        "er" | "erdos-renyi" => graphs::erdos_renyi(n, 0.3, rng),
        "sensor" => graphs::sensor(n, rng),
        "ring" => graphs::ring(n),
        "minnesota" => graphs::real_world_substitute(RealWorldGraph::Minnesota, scale, rng),
        "protein" => graphs::real_world_substitute(RealWorldGraph::HumanProtein, scale, rng),
        "email" => graphs::real_world_substitute(RealWorldGraph::Email, scale, rng),
        "facebook" => graphs::real_world_substitute(RealWorldGraph::Facebook, scale, rng),
        other => bail!("unknown --graph {other}"),
    })
}

/// `fastes gft` — build a graph, factor its Laplacian, report accuracy.
pub fn gft(a: &Args) -> crate::Result<()> {
    let seed: u64 = a.get("seed", 1)?;
    let alpha: usize = a.get("alpha", 2)?;
    let sweeps: usize = a.get("sweeps", 2)?;
    let mut rng = Rng64::new(seed);
    let graph = build_graph(a, &mut rng)?;
    let n = graph.n;
    let g = budget(alpha, n);
    println!("graph n={n} |E|={} directed={}", graph.num_edges(), a.has("directed"));
    let t0 = Instant::now();
    if a.has("directed") {
        let d = graph.randomly_directed(&mut rng);
        let l = d.laplacian();
        let f = GeneralFactorizer::new(
            &l,
            g,
            GeneralOptions { max_sweeps: sweeps, ..Default::default() },
        )
        .run();
        println!(
            "T-chain m={} rel_err={:.4} flops/apply={} (dense {}) elapsed={:.2?}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n,
            t0.elapsed()
        );
    } else {
        let l = graph.laplacian();
        let f = SymFactorizer::new(
            &l,
            g,
            SymOptions { max_sweeps: sweeps, ..Default::default() },
        )
        .run();
        println!(
            "G-chain g={} rel_err={:.4} flops/apply={} (dense {}) elapsed={:.2?}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n,
            t0.elapsed()
        );
    }
    Ok(())
}

/// `fastes serve` — factor a community-graph GFT, serve batched requests
/// through the coordinator, report latency/throughput.
pub fn serve(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 128)?;
    let alpha: usize = a.get("alpha", 2)?;
    let requests: usize = a.get("requests", 2000)?;
    let batch: usize = a.get("batch", 8)?;
    let backend_kind = a.get_str("backend", "native");
    let artifacts = PathBuf::from(a.get_str("artifacts", "artifacts"));
    let seed: u64 = a.get("seed", 1)?;
    let scheduled = a.has("scheduled");
    let threads: usize = a.get("threads", default_threads())?;
    if scheduled && backend_kind != "native" {
        bail!("--scheduled is only supported with --backend native (got {backend_kind})");
    }

    let mut rng = Rng64::new(seed);
    let graph = graphs::community(n, &mut rng);
    let l = graph.laplacian();
    let g = budget(alpha, n);
    println!("factoring community graph n={n} |E|={} with g={g}…", graph.num_edges());
    let f = SymFactorizer::new(&l, g, SymOptions { max_sweeps: 1, ..Default::default() }).run();
    println!("factored: rel_err={:.4}", f.relative_error(&l));
    let plan = f.chain.to_plan();

    let config = ServeConfig { max_batch: batch, ..Default::default() };
    let coordinator = match backend_kind.as_str() {
        "native" => {
            let p = plan.clone();
            Coordinator::start(
                move || {
                    Ok(Box::new(NativeGftBackend::with_schedule(
                        p,
                        TransformDirection::Forward,
                        batch,
                        None,
                        scheduled,
                        threads,
                    )) as Box<dyn Backend>)
                },
                config,
            )?
        }
        "pjrt" => {
            let p = plan.clone();
            Coordinator::start(
                move || {
                    let store = crate::runtime::ArtifactStore::open(&artifacts)?;
                    Ok(Box::new(PjrtGftBackend::new(
                        store,
                        TransformDirection::Forward,
                        p,
                        batch,
                        None,
                    )?) as Box<dyn Backend>)
                },
                config,
            )?
        }
        other => bail!("--backend must be native|pjrt (got {other})"),
    };

    println!(
        "serving {requests} requests (backend={backend_kind}{}, batch={batch})…",
        if scheduled { format!(" scheduled/{threads}t") } else { String::new() }
    );
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(64);
    let mut checked = 0usize;
    for k in 0..requests {
        let sig: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        pending.push((sig.clone(), coordinator.submit(sig)?));
        if pending.len() >= 64 || k + 1 == requests {
            for (sig, t) in pending.drain(..) {
                let out = t.wait()?;
                // spot-check against the native f64 path
                if checked < 16 {
                    let mut want: Vec<f64> = sig.iter().map(|&v| v as f64).collect();
                    f.chain.apply_vec_t(&mut want);
                    for (w, o) in want.iter().zip(out.iter()) {
                        assert!((*w as f32 - o).abs() < 1e-2, "serving mismatch");
                    }
                    checked += 1;
                }
            }
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = coordinator.shutdown();
    println!("throughput: {:.0} req/s over {:.2}s", requests as f64 / elapsed, elapsed);
    println!("metrics: {}", m.line());
    Ok(())
}

/// `fastes eigen` — symmetric eigensolver smoke test.
pub fn eigen(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 256)?;
    let seed: u64 = a.get("seed", 1)?;
    let mut rng = Rng64::new(seed);
    let x = Mat::randn(n, n, &mut rng);
    let s = &x + &x.transpose();
    let t0 = Instant::now();
    let e = eigh(&s);
    let rel = e.reconstruct().fro_dist_sq(&s) / s.fro_norm_sq();
    println!(
        "eigh n={n}: reconstruction rel²={rel:.3e}, λ_max={:.4}, λ_min={:.4}, elapsed={:.2?}",
        e.values[0],
        e.values[n - 1],
        t0.elapsed()
    );
    Ok(())
}

/// `fastes schedule` — compile a butterfly chain into conflict-free
/// layers, report the schedule shape (layer count / depth / width) and
/// time sequential vs level-scheduled parallel apply.
pub fn schedule(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 512)?;
    let alpha: usize = a.get("alpha", 2)?;
    let batch: usize = a.get("batch", 32)?;
    let threads: usize = a.get("threads", default_threads())?;
    let seed: u64 = a.get("seed", 1)?;
    let g = budget(alpha, n);
    let mut rng = Rng64::new(seed);

    let gchain = random_gplan(n, g, &mut rng);
    let gcp = gchain.compile();
    let tchain = random_tplan(n, g, &mut rng);
    let tcp = tchain.compile();
    for (label, stats) in [("G-chain", gcp.stats()), ("T-chain", tcp.stats())] {
        println!(
            "{label}: n={n} stages={} layers={} depth-reduction={:.1}x max-width={}",
            stats.stages,
            stats.layers,
            stats.mean_width,
            stats.max_width
        );
    }

    // timing: sequential plan apply vs compiled apply at 1 and N threads
    let plan = gchain.to_plan();
    let signals: Vec<Vec<f32>> = (0..batch)
        .map(|_| (0..n).map(|_| rng.randn() as f32).collect())
        .collect();
    let mut seq_block = SignalBlock::from_signals(&signals);
    let t_seq = crate::bench_util::bench("sequential apply", 5, 0.05, || {
        crate::transforms::apply_gchain_batch_f32(&plan, &mut seq_block);
        seq_block.data[0]
    });
    let compiled = CompiledPlan::from_plan(&plan, ChainKind::G);
    let mut one_block = SignalBlock::from_signals(&signals);
    let t_one = crate::bench_util::bench("scheduled apply (1 thread)", 5, 0.05, || {
        compiled.apply_batch(&mut one_block, 1);
        one_block.data[0]
    });
    let mut par_block = SignalBlock::from_signals(&signals);
    let t_par =
        crate::bench_util::bench(&format!("scheduled apply ({threads} threads)"), 5, 0.05, || {
            compiled.apply_batch(&mut par_block, threads);
            par_block.data[0]
        });
    println!("{}", t_seq.line());
    println!("{}", t_one.line());
    println!("{}", t_par.line());
    println!(
        "batch={batch}: scheduled/1t vs sequential {:.2}x, scheduled/{threads}t vs sequential {:.2}x",
        t_seq.min_s / t_one.min_s,
        t_seq.min_s / t_par.min_s
    );
    Ok(())
}

/// `fastes bench-apply` — quick butterfly vs dense apply timing.
pub fn bench_apply(a: &Args) -> crate::Result<()> {
    let n: usize = a.get("n", 1024)?;
    let alpha: usize = a.get("alpha", 2)?;
    let g = budget(alpha, n);
    let mut rng = Rng64::new(3);
    let plan = random_gplan(n, g, &mut rng).to_plan();
    let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
    let dense: Vec<f32> = (0..n * n).map(|_| rng.randn() as f32).collect();
    let mut y = vec![0f32; n];
    let td = crate::bench_util::bench("dense gemv", 7, 0.05, || {
        for (r, yr) in y.iter_mut().enumerate() {
            let row = &dense[r * n..(r + 1) * n];
            let mut acc = 0f32;
            for (u, v) in row.iter().zip(x.iter()) {
                acc += u * v;
            }
            *yr = acc;
        }
        y[0]
    });
    let mut block = SignalBlock::from_signals(&[x.clone()]);
    let tb = crate::bench_util::bench("butterfly apply", 7, 0.05, || {
        crate::transforms::apply_gchain_batch_f32(&plan, &mut block);
        block.data[0]
    });
    println!("{}", td.line());
    println!("{}", tb.line());
    println!(
        "n={n} g={g}: flop ratio {:.2}, measured speedup {:.2}",
        (2 * n * n) as f64 / (6 * g) as f64,
        td.min_s / tb.min_s
    );
    Ok(())
}
