//! Shared accuracy metrics for the figure harnesses.

use crate::linalg::Mat;
use crate::transforms::GChain;

/// Relative Frobenius error `‖M − M̄‖_F / ‖M‖_F`.
pub fn relative_error(m: &Mat, approx: &Mat) -> f64 {
    (m.fro_dist_sq(approx) / m.fro_norm_sq().max(1e-300)).sqrt()
}

/// Eigenspace approximation error used by Fig. 2:
/// `‖U − Ū·P‖²_F / ‖U‖²_F` where `P` aligns `Ū` to `U` by (i) ordering
/// columns by the estimated eigenvalues (descending, matching `U`'s
/// convention) and (ii) flipping column signs to maximize per-column
/// correlation — both are symmetries of the factorization (an eigenvector
/// is defined up to sign; the estimated spectrum defines the order).
pub fn eigenspace_error(u_true: &Mat, chain: &GChain, est_spectrum: &[f64]) -> f64 {
    let n = u_true.rows();
    assert_eq!(est_spectrum.len(), n);
    let ubar = chain.to_dense();
    // column order by estimated eigenvalue, descending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| est_spectrum[b].partial_cmp(&est_spectrum[a]).unwrap());
    let mut err = 0.0;
    for (target_col, &src_col) in order.iter().enumerate() {
        // sign alignment
        let mut dot = 0.0;
        for r in 0..n {
            dot += u_true[(r, target_col)] * ubar[(r, src_col)];
        }
        let sgn = if dot >= 0.0 { 1.0 } else { -1.0 };
        for r in 0..n {
            let d = u_true[(r, target_col)] - sgn * ubar[(r, src_col)];
            err += d * d;
        }
    }
    err / u_true.fro_norm_sq().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, Rng64};

    #[test]
    fn relative_error_zero_for_equal() {
        let mut rng = Rng64::new(801);
        let m = Mat::randn(5, 5, &mut rng);
        assert_eq!(relative_error(&m, &m), 0.0);
    }

    #[test]
    fn eigenspace_error_zero_for_perfect_factorization() {
        // factor U exactly with enough transforms, then the aligned error
        // must vanish even under column permutation/sign symmetry
        let mut rng = Rng64::new(802);
        let x = Mat::randn(6, 6, &mut rng);
        let s = &x + &x.transpose();
        let e = eigh(&s);
        let r = crate::baselines::factor_orthonormal(&e.vectors, &vec![1.0; 6], 60);
        let err = eigenspace_error(&e.vectors, &r.chain, &e.values);
        assert!(err < 1e-10, "err {err}");
    }

    #[test]
    fn eigenspace_error_invariant_to_sign_flips() {
        let mut rng = Rng64::new(803);
        let x = Mat::randn(5, 5, &mut rng);
        let s = &x + &x.transpose();
        let e = eigh(&s);
        let r = crate::baselines::factor_orthonormal(&e.vectors, &vec![1.0; 5], 10);
        let base = eigenspace_error(&e.vectors, &r.chain, &e.values);
        // flipping the sign of a whole column of U(true) must not blow up
        // the metric beyond the column-alignment bound
        let mut u2 = e.vectors.clone();
        u2.scale_col(2, -1.0);
        let flipped = eigenspace_error(&u2, &r.chain, &e.values);
        assert!((base - flipped).abs() < 1e-9, "{base} vs {flipped}");
    }
}
