//! Command-line interface of the `fastes` binary.
//!
//! Hand-rolled argument parsing (no clap in the offline crate snapshot):
//! `fastes <command> [--flag value]...`. Commands:
//!
//! * `repro --fig N` — regenerate a paper figure (see [`figures`]).
//! * `factor` — factor a random matrix and report accuracy
//!   (`--threads` runs the deterministic parallel factorizer;
//!   `--checkpoint BASE` persists resumable `.fastplan`/`.fastckpt`
//!   pairs and `--resume BASE` continues a halted/killed run,
//!   reproducing the uninterrupted result bitwise).
//! * `refactor` — warm-start refactorization for drifted graphs: replay
//!   a saved plan's chain against a drifted Laplacian, re-measure the
//!   Lemma-1 spectrum and error certificate against the drifted matrix
//!   (never inherited), optionally grow to `--error-budget`.
//! * `gft` — build a graph, factor its Laplacian, report the fast-GFT
//!   accuracy and flop counts.
//! * `filter` — run the fused spectral-operator workloads: a kernel
//!   graph filter (fused single-pass, verified bitwise against the
//!   unfused reference), a Hammond wavelet bank (`--wavelet J`) or
//!   top-k spectral compression (`--topk K` / `--threshold T`).
//! * `serve` — run the serving coordinator on a factored GFT and report
//!   latency/throughput (`--exec pool` executes the fused plan on the
//!   persistent worker pool; `spawn`/`seq` are the legacy strategies;
//!   `auto` / `--autotune` resolve the engine by startup
//!   micro-calibration, `--tune-profile` reloads a saved `.fasttune`
//!   sweep with zero startup cost).
//! * `schedule` — compile a chain into conflict-free layers + fused
//!   superstages and report layer counts/depth plus sequential vs spawn
//!   vs pooled apply timings.
//! * `tune` — run the execution-engine micro-calibration sweep for an
//!   operator, print the score table, optionally persist it as a
//!   `.fasttune` profile.
//! * `bench` — machine-readable apply benchmark (sequential vs spawn vs
//!   pooled; `--json` writes `BENCH_apply.json` incl. the dispatched
//!   `kernel_isa`; `--autotune` adds the auto-tuned mode and stamps the
//!   tuned config). `bench --factor` benchmarks plan *construction*
//!   instead (ns/step per kind/n/threads, `BENCH_factor.json`).
//! * `bakeoff` — our Givens factorizer vs the baseline methods
//!   (greedy-givens / jacobi / direct-U / low-rank) on the
//!   flops-vs-error frontier per graph family, all scored with the
//!   shared certificate metric; `--json` writes `BENCH_error.json`.
//! * `kernels` — report the SIMD kernel dispatch of this host (detected
//!   / default / available ISAs).
//! * `eigen` — eigendecomposition smoke (substrate sanity).
//! * `bench-apply` — quick butterfly-vs-dense apply timing.

pub mod commands;
pub mod figures;
pub mod metrics;

use std::collections::HashMap;

use anyhow::{anyhow, bail};

/// Parsed command line: a command word plus `--key value` flags
/// (bare `--flag` becomes `"true"`).
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The command word.
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding `argv[0]`).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> crate::Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            flags.insert(key.to_string(), value);
        }
        Ok(Args { command, flags })
    }

    /// String flag with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Parsed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> crate::Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{key}: cannot parse '{v}'")),
        }
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, key: &str, default: &[usize]) -> crate::Result<Vec<usize>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|_| anyhow!("flag --{key}: bad item '{p}'")))
                .collect(),
        }
    }

    /// Boolean presence flag.
    pub fn has(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v != "false").unwrap_or(false)
    }
}

/// Top-level dispatch.
pub fn run(args: Args) -> crate::Result<()> {
    match args.command.as_str() {
        "repro" => figures::run(&args),
        "factor" => commands::factor(&args),
        "refactor" => commands::refactor(&args),
        "gft" => commands::gft(&args),
        "filter" => commands::filter(&args),
        "serve" => commands::serve(&args),
        "schedule" => commands::schedule(&args),
        "tune" => commands::tune(&args),
        "bench" => commands::bench(&args),
        "bakeoff" => commands::bakeoff(&args),
        "kernels" => commands::kernels(&args),
        "eigen" => commands::eigen(&args),
        "bench-apply" => commands::bench_apply(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try 'fastes help')"),
    }
}

const HELP: &str = "\
fastes — fast approximate eigenspaces & fast graph Fourier transforms
  (reproduction of Rusu & Rosasco, IEEE TSP 2021)

USAGE: fastes <command> [--flag value]...

COMMANDS
  repro --fig N        regenerate paper figure N (1..6)
                       [--scale F] [--reals R] [--sizes a,b] [--alphas a,b]
                       [--seed S] [--full]
  factor               factor a random matrix
                       [--kind sym|psd|gen] [--n N] [--budget G] [--seed S]
                       [--sweeps K] [--eps E] [--full-update]
                       [--threads T] [--factor-min-work W]  (parallel
                       factorizer — same chain at any thread count)
                       [--checkpoint BASE] [--checkpoint-every N]
                       (persist BASE.fastplan + BASE.fastckpt every N
                       progress steps; default N=100)
                       [--halt-after K]  (stop after K progress steps,
                       checkpointing the partial run)
                       [--resume BASE]  (continue a checkpointed run —
                       bitwise-identical to the uninterrupted result)
                       [--error-budget EPS]  (grow the budget — doubling
                       from --budget, capped at --max-g — until the
                       measured relative error meets EPS; --save-plan
                       then writes a v3 .fastplan carrying the error
                       certificate) [--max-g G]
                       [--save-plan FILE.fastplan]
  refactor             warm-start refactorization for a drifted graph
                       --from FILE.fastplan  (donor plan; its chain seeds
                       the run, but spectrum + certificate are
                       re-measured against the drifted matrix)
                       [--graph G] [--seed S]  (regenerate the base
                       graph; n comes from the donor plan)
                       [--drift K] [--drift-seed D]  (apply K
                       deterministic edge add/remove/reweight updates)
                       [--error-budget EPS] [--max-g G]  (grow the chain
                       until the re-measured certificate meets EPS)
                       [--sweeps K] [--threads T] [--factor-min-work W]
                       [--compare-cold]  (also run the cold budgeted
                       baseline on the drifted matrix and report the
                       sweeps/wall-clock saving)
                       [--save-plan FILE.fastplan]  (v3 artifact with the
                       re-measured certificate)
  gft                  fast GFT of a graph Laplacian
                       [--graph community|er|sensor|ring|masked-grid|
                        minnesota|protein|email|facebook]
                       [--n N] [--alpha A] [--directed] [--seed S]
                       [--mask F]  (masked-grid: fraction of vertices
                       masked out, default 0.2)
                       [--save-plan FILE.fastplan]  (v2 artifact carrying
                       the Lemma-1 spectrum — spectral operators need it)
  filter               fused spectral operators on a factored eigenspace
                       [--plan FILE.fastplan | --graph G --n N --alpha A]
                       [--response heat|lowpass|highpass|hammond]
                       [--param F]  (diffusion time / cutoff / scale,
                       default 0.5)
                       [--wavelet J]  (Hammond bank: scaling + J wavelet
                       bands over one shared reverse traversal)
                       [--topk K] [--threshold T]  (sparse spectral
                       compression: largest-|v| coefficients)
                       [--batch B] [--seed S] [--exec seq|spawn|pool|auto]
                       (filter path asserts fused == unfused bitwise and
                       prints the one-reverse + one-forward flop account)
  serve                serve batched GFT requests
                       [--backend native|pjrt] [--requests N] [--batch B]
                       [--alpha A] [--artifacts DIR]
                       [--plan FILE.fastplan]  (serve a saved plan
                       artifact instead of refactorizing)
                       [--exec pool|spawn|seq|auto] [--threads T]
                       [--min-work W] [--layer-min-work W] [--tile C]
                       [--kernel auto|scalar|avx2|avx512|neon]
                       [--autotune off|quick|full]  (startup
                       micro-calibration picks the engine config)
                       [--tune-profile FILE.fasttune]  (reload a saved
                       sweep — zero startup sweeps)
                       (tuning flags reach the selected ExecPolicy engine;
                       --scheduled is the legacy alias for --exec spawn)
                       [--listen ADDR]  (hardened TCP front-end speaking
                       the length-prefixed JSON protocol — forward/
                       adjoint/filter/wavelet/topk/metrics/upload_plan
                       — with deadlines,
                       priorities, typed rejections and graceful drain
                       on SIGTERM; native backend only)
                       [--registry-cap N]  (resident-plan LRU capacity,
                       default 64) [--plan-dir DIR]  (load
                       {checksum:016x}.fastplan artifacts on demand)
                       [--max-error EPS]  (refuse to route to plans whose
                       .fastplan error certificate exceeds EPS, or that
                       carry none — typed unsupported_plan rejection;
                       also refuses hot-swapping a refactored plan whose
                       re-measured certificate misses EPS)
                       [--watch-graph FILE]  (poll FILE for a drifted
                       matrix — JSON {\"matrix\":[..n*n..]} — and
                       warm-refactor + hot-swap the default plan in the
                       background; --listen only)
                       [--refactor-budget EPS]  (grow warm-started chains
                       until the re-measured certificate meets EPS)
  schedule             level-schedule a chain, report layers/depth/
                       superstages and time sequential vs spawn vs pooled
                       apply [--n N] [--alpha A] [--batch B] [--threads T]
                       [--min-work W] [--layer-min-work W] [--tile C]
                       [--kernel K] [--seed S]
  tune                 micro-calibration sweep: score tile_cols x
                       min_work x engine x kernel candidates for a plan
                       and print the table [--plan FILE.fastplan | --n N
                       --alpha A --seed S] [--batch B]
                       [--effort quick|full] [--out FILE.fasttune]
                       [--json]
  bench                machine-readable apply bench: sequential vs spawn
                       vs pooled (ns/stage, GB/s; records kernel_isa)
                       [--sizes a,b,c] [--batch B] [--alpha A] [--seed S]
                       [--threads T] [--kernel K] [--json] [--out PATH]
                       [--autotune off|quick|full]  (adds the auto-tuned
                       mode and stamps its config into the JSON)
                       [--factor]  (benchmark plan construction instead:
                       sym/gen ns-per-step at 1 vs T threads, writes
                       BENCH_factor.json; [--sweeps K])
                       [--filter]  (benchmark the fused spectral filter
                       against the unfused adjoint+scale+forward route,
                       seq and pooled; --json stamps the fused-vs-unfused
                       ns/stage rows into BENCH_apply.json)
                       [--refactor]  (warm-vs-cold iterations-to-budget
                       on drifted graphs: cold-factor the base Laplacian,
                       drift it, reach --error-budget cold vs warm-start;
                       writes BENCH_refactor.json; [--families f,g]
                       [--drift K] [--error-budget EPS])
  bakeoff              factorizer bake-off on the flops-vs-error frontier:
                       givens (ours) vs greedy-givens vs jacobi vs
                       direct-U vs flop-matched low-rank, per graph
                       family, all scored with the certificate metric
                       [--n N] [--alphas a,b,c] [--sweeps K] [--seed S]
                       [--families er,community,masked-grid]
                       [--json] [--out BENCH_error.json]
  kernels              report SIMD kernel dispatch: detected / default /
                       available ISAs (FASTES_KERNEL and --kernel pin it)
  eigen                symmetric eigensolver smoke [--n N] [--seed S]
  bench-apply          butterfly vs dense apply timing [--n N] [--alpha A]
  help                 this text
";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flags() {
        let a = Args::parse(
            ["repro", "--fig", "3", "--full", "--sizes", "128,256"].map(String::from),
        )
        .unwrap();
        assert_eq!(a.command, "repro");
        assert_eq!(a.get("fig", 0usize).unwrap(), 3);
        assert!(a.has("full"));
        assert!(!a.has("absent"));
        assert_eq!(a.get_list("sizes", &[]).unwrap(), vec![128, 256]);
        assert_eq!(a.get("reals", 7usize).unwrap(), 7);
    }

    #[test]
    fn parses_checkpoint_flags() {
        let a = Args::parse(
            ["factor", "--checkpoint", "ck/run", "--checkpoint-every", "50", "--halt-after", "80"]
                .map(String::from),
        )
        .unwrap();
        assert_eq!(a.get_str("checkpoint", ""), "ck/run");
        assert_eq!(a.get("checkpoint-every", 0usize).unwrap(), 50);
        assert!(a.has("halt-after"));
        assert_eq!(a.get("halt-after", 0usize).unwrap(), 80);
        assert_eq!(a.get_str("resume", ""), "");
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(["repro", "oops"].map(String::from)).is_err());
    }

    #[test]
    fn bad_flag_value() {
        let a = Args::parse(["repro", "--fig", "xyz"].map(String::from)).unwrap();
        assert!(a.get("fig", 0usize).is_err());
    }
}
