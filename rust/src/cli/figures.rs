//! Figure harnesses: regenerate every table/figure of the paper's
//! evaluation (§5 + supplementary). Each `figN` prints the series the
//! paper plots and returns the rows for programmatic use; `make repro`
//! tees them into `results/`.
//!
//! Scale: by default the harnesses run a *reduced* configuration
//! (`--scale 0.25`, 3 realizations, sizes ≤ 256 for the directed/T cases)
//! so the whole suite completes in minutes; `--full` restores the paper's
//! sizes. The qualitative shapes (method ordering, crossovers, trends in
//! α and n) are scale-invariant — see EXPERIMENTS.md.

use anyhow::bail;

use super::metrics::eigenspace_error;
use super::Args;
use crate::baselines;
use crate::factor::{
    GeneralFactorizer, GeneralOptions, SpectrumRule, SymFactorizer, SymOptions,
};
use crate::graphs::{self, Graph, RealWorldGraph};
use crate::linalg::{eigh, mean_std, Mat, Rng64};
use crate::transforms::{GChain, GKind, GTransform, TChain, TTransform};

/// Common harness options (parsed from flags).
#[derive(Clone, Debug)]
pub struct FigOptions {
    /// Graph-size scale factor for the real-world substitutes.
    pub scale: f64,
    /// Monte-Carlo realizations.
    pub reals: usize,
    /// Graph sizes `n` (Figs. 1 and 5).
    pub sizes: Vec<usize>,
    /// Transform-budget multipliers `α` (`g = α·n·log₂n`).
    pub alphas: Vec<usize>,
    /// Paper-scale run.
    pub full: bool,
    /// Base RNG seed.
    pub seed: u64,
    /// Iterative sweeps for Algorithm 1.
    pub sweeps: usize,
}

impl FigOptions {
    fn from_args(a: &Args) -> crate::Result<Self> {
        let full = a.has("full");
        Ok(FigOptions {
            scale: a.get("scale", if full { 1.0 } else { 0.25 })?,
            reals: a.get("reals", if full { 10 } else { 3 })?,
            sizes: a.get_list("sizes", if full { &[128, 256, 512] } else { &[128, 256] })?,
            alphas: a.get_list("alphas", if full { &[1, 2, 3, 4, 5, 6] } else { &[1, 2, 3, 4] })?,
            full,
            seed: a.get("seed", 2021)?,
            sweeps: a.get("sweeps", 2)?,
        })
    }
}

/// One printed data point.
#[derive(Clone, Debug)]
pub struct Row {
    /// Series label (figure, family, method, …).
    pub label: String,
    /// x-axis value (α or g).
    pub x: f64,
    /// Mean of the metric.
    pub mean: f64,
    /// Std of the metric.
    pub std: f64,
}

fn emit(rows: &mut Vec<Row>, label: impl Into<String>, x: f64, samples: &[f64]) {
    let (m, s) = mean_std(samples);
    let label = label.into();
    println!("{label:<58} x={x:<8} mean={m:.6} std={s:.6}");
    rows.push(Row { label, x, mean: m, std: s });
}

/// `g = α·n·log₂n` (the paper's budget rule).
pub fn budget(alpha: usize, n: usize) -> usize {
    (alpha as f64 * n as f64 * (n as f64).log2()).round() as usize
}

/// Dispatch `repro --fig N`.
pub fn run(args: &Args) -> crate::Result<()> {
    let fig: usize = args.get("fig", 0)?;
    let opts = FigOptions::from_args(args)?;
    match fig {
        1 => {
            fig1(&opts);
        }
        2 => {
            fig2(&opts);
        }
        3 => {
            fig3(&opts);
        }
        4 => {
            fig4(&opts);
        }
        5 => {
            fig5(&opts);
        }
        6 => {
            fig6(&opts);
        }
        _ => bail!("--fig must be 1..6"),
    }
    Ok(())
}

fn sym_factor(l: &Mat, g: usize, sweeps: usize) -> (GChain, Vec<f64>, f64) {
    let f = SymFactorizer::new(
        l,
        g,
        SymOptions { max_sweeps: sweeps, eps: 1e-2, ..Default::default() },
    )
    .run();
    let rel = f.relative_error(l);
    (f.chain, f.spectrum, rel)
}

fn gen_factor(c: &Mat, m: usize, sweeps: usize) -> (TChain, Vec<f64>, f64) {
    let f = GeneralFactorizer::new(
        c,
        m,
        GeneralOptions { max_sweeps: sweeps, eps: 1e-2, ..Default::default() },
    )
    .run();
    let rel = f.relative_error(c);
    (f.chain, f.spectrum, rel)
}

fn make_family(family: &str, n: usize, rng: &mut Rng64) -> Graph {
    match family {
        "community" => graphs::community(n, rng),
        "erdos-renyi" => graphs::erdos_renyi(n, 0.3, rng),
        "sensor" => graphs::sensor(n, rng),
        other => panic!("unknown family {other}"),
    }
}

/// **Fig. 1** — approximation accuracy (mean ± std) of the Laplacian vs
/// `g = α·n·log₂n` on community / Erdős–Rényi(p=0.3) / sensor graphs;
/// top: undirected (G-transforms), bottom: directed (T-transforms,
/// random edge orientation with p=1/2). Spectrum rule: `'update'`.
pub fn fig1(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 1 — random-graph Laplacian accuracy vs alpha (g = a·n·log2 n)");
    let mut rows = Vec::new();
    for family in ["community", "erdos-renyi", "sensor"] {
        for &n in &o.sizes {
            for &alpha in &o.alphas {
                let g = budget(alpha, n);
                let mut errs = Vec::new();
                for r in 0..o.reals {
                    let mut rng = Rng64::new(o.seed ^ (r as u64) << 8 ^ n as u64);
                    let graph = make_family(family, n, &mut rng);
                    let l = graph.laplacian();
                    let (_, _, rel) = sym_factor(&l, g, o.sweeps);
                    errs.push(rel);
                }
                emit(&mut rows, format!("fig1/undirected/{family}/n={n}"), alpha as f64, &errs);
            }
        }
        // directed: T-transforms are O(n²)-per-factor at init → cap size
        // unless --full
        let dir_sizes: Vec<usize> = if o.full {
            o.sizes.clone()
        } else {
            o.sizes.iter().copied().filter(|&n| n <= 128).collect()
        };
        for &n in &dir_sizes {
            for &alpha in &o.alphas {
                let m = budget(alpha, n);
                let mut errs = Vec::new();
                for r in 0..o.reals {
                    let mut rng = Rng64::new(o.seed ^ 0xD17 ^ (r as u64) << 8 ^ n as u64);
                    let graph = make_family(family, n, &mut rng).randomly_directed(&mut rng);
                    let l = graph.laplacian();
                    let (_, _, rel) = gen_factor(&l, m, o.sweeps.min(1));
                    errs.push(rel);
                }
                emit(&mut rows, format!("fig1/directed/{family}/n={n}"), alpha as f64, &errs);
            }
        }
    }
    rows
}

/// The four Fig.-2 graphs as structure-matched substitutes.
fn fig2_graphs(o: &FigOptions) -> Vec<(String, Graph)> {
    RealWorldGraph::all()
        .into_iter()
        .map(|w| {
            let mut rng = Rng64::new(o.seed ^ 0xF16_2);
            (w.name().to_string(), graphs::real_world_substitute(w, o.scale, &mut rng))
        })
        .collect()
}

/// **Fig. 2** — eigenspace accuracy `‖U − Ū‖²_F/‖U‖²_F` vs `g` on the
/// four real-world graphs (structure-matched substitutes — DESIGN.md §4):
/// proposed (G-transforms) vs truncated Jacobi [LeMagoarou18] vs greedy
/// Givens [Kondor14 proxy] vs the given-U Givens factorization
/// [RusuRosasco19, standing in for the L1 method of FrerixBruna19, which
/// also requires the precomputed eigenspace].
pub fn fig2(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 2 — eigenspace accuracy vs g on real-world graph substitutes");
    println!("# (scale {}: n is {}x the original)", o.scale, o.scale);
    let mut rows = Vec::new();
    for (name, graph) in fig2_graphs(o) {
        let n = graph.n;
        let l = graph.laplacian();
        let e = eigh(&l);
        for &alpha in &o.alphas {
            let g = budget(alpha, n);
            // proposed
            let f = SymFactorizer::new(
                &l,
                g,
                SymOptions { max_sweeps: o.sweeps, ..Default::default() },
            )
            .run();
            let err = eigenspace_error(&e.vectors, &f.chain, &f.spectrum);
            emit(&mut rows, format!("fig2/{name}/proposed"), g as f64, &[err]);
            // truncated Jacobi
            let j = baselines::truncated_jacobi(&l, g);
            let err = eigenspace_error(&e.vectors, &j.chain, &j.spectrum);
            emit(&mut rows, format!("fig2/{name}/jacobi"), g as f64, &[err]);
            // greedy Givens (γ-score)
            let gg = baselines::greedy_givens(&l, g);
            let err = eigenspace_error(&e.vectors, &gg.chain, &gg.spectrum);
            emit(&mut rows, format!("fig2/{name}/greedy-givens"), g as f64, &[err]);
            // given-U factorization
            let du = baselines::factor_orthonormal(&e.vectors, &vec![1.0; n], g);
            let err = eigenspace_error(&e.vectors, &du.chain, &e.values);
            emit(&mut rows, format!("fig2/{name}/given-U"), g as f64, &[err]);
        }
    }
    rows
}

/// **Fig. 3** — overall Laplacian accuracy
/// `‖L − Ū diag(λ̄) Ūᵀ‖_F/‖L‖_F` vs `g` for the same four graphs
/// (proposed method with spectrum updates).
pub fn fig3(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 3 — Laplacian accuracy vs g on real-world graph substitutes");
    let mut rows = Vec::new();
    for (name, graph) in fig2_graphs(o) {
        let n = graph.n;
        let l = graph.laplacian();
        for &alpha in &o.alphas {
            let g = budget(alpha, n);
            let (_, _, rel) = sym_factor(&l, g, o.sweeps);
            emit(&mut rows, format!("fig3/{name}/proposed"), g as f64, &[rel]);
        }
    }
    rows
}

/// **Fig. 4** — Erdős–Rényi `n = 1024` (reduced: `n = 256` unless
/// `--full`): approximate `L` directly from `L` (ours, ± spectrum update)
/// vs approximating the explicitly-given eigendecomposition
/// ([RusuRosasco19]: plain `U` and the weighted eigenspace `U·diag(λ)`).
/// Metric: relative Laplacian error.
pub fn fig4(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 4 — given-EVD vs matrix-only approximation (Erdos-Renyi)");
    let n = if o.full { 1024 } else { 256 };
    let mut rows = Vec::new();
    let mut rng = Rng64::new(o.seed ^ 0xF16_4);
    let graph = graphs::erdos_renyi(n, 0.3, &mut rng);
    let l = graph.laplacian();
    let e = eigh(&l);
    for &alpha in &o.alphas {
        let g = budget(alpha, n);
        // (a) ours, update rule
        let (_, _, rel) = sym_factor(&l, g, o.sweeps);
        emit(&mut rows, "fig4/proposed-update", alpha as f64, &[rel]);
        // (b) ours, true spectrum given
        let f = SymFactorizer::new(
            &l,
            g,
            SymOptions {
                spectrum: SpectrumRule::Original(e.values.clone()),
                max_sweeps: o.sweeps,
                ..Default::default()
            },
        )
        .run();
        emit(&mut rows, "fig4/proposed-true-spectrum", alpha as f64, &[f.relative_error(&l)]);
        // (c) given-U factorization, unweighted
        let du = baselines::factor_orthonormal(&e.vectors, &vec![1.0; n], g);
        let spec = crate::factor::oracle::lemma1_spectrum(&l, &du.chain);
        let rel = (du.chain.objective(&l, &spec) / l.fro_norm_sq()).sqrt();
        emit(&mut rows, "fig4/given-U-unweighted", alpha as f64, &[rel]);
        // (d) given-U factorization, weighted by |λ|
        let w: Vec<f64> = e.values.iter().map(|v| v.abs().max(1e-6)).collect();
        let du = baselines::factor_orthonormal(&e.vectors, &w, g);
        let spec = crate::factor::oracle::lemma1_spectrum(&l, &du.chain);
        let rel = (du.chain.objective(&l, &spec) / l.fro_norm_sq()).sqrt();
        emit(&mut rows, "fig4/given-U-weighted", alpha as f64, &[rel]);
    }
    rows
}

/// **Fig. 5 (supp)** — random unstructured matrices: symmetric indefinite
/// `S = X+Xᵀ`, PSD `S = XXᵀ`, general `C = X`; proposed factorization vs
/// the best rank-`r` baseline at matched apply-flops
/// (`r = 3·α·log₂n` symmetric, `r = α·log₂n` general; both ≈ `2rn`
/// flops).
pub fn fig5(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 5 — random matrices vs low-rank baseline at matched flops");
    let mut rows = Vec::new();
    for &n in &o.sizes {
        for &alpha in &o.alphas {
            let logn = (n as f64).log2();
            let mut e_indef = Vec::new();
            let mut e_psd = Vec::new();
            let mut e_gen = Vec::new();
            let mut lr_sym_indef = Vec::new();
            let mut lr_sym_psd = Vec::new();
            let mut lr_gen = Vec::new();
            for r in 0..o.reals {
                let mut rng = Rng64::new(o.seed ^ 0xF16_5 ^ ((r as u64) << 16) ^ n as u64);
                let x = Mat::randn(n, n, &mut rng);
                // symmetric indefinite
                let s = &x + &x.transpose();
                let g = budget(alpha, n);
                let (_, _, rel) = sym_factor(&s, g, o.sweeps);
                e_indef.push(rel);
                let r_sym = (3.0 * alpha as f64 * logn).round() as usize;
                lr_sym_indef
                    .push((baselines::lowrank_error_symmetric(&s, r_sym) / s.fro_norm_sq()).sqrt());
                // PSD
                let p = x.matmul(&x.transpose());
                let (_, _, rel) = sym_factor(&p, g, o.sweeps);
                e_psd.push(rel);
                lr_sym_psd
                    .push((baselines::lowrank_error_symmetric(&p, r_sym) / p.fro_norm_sq()).sqrt());
                // general (T-transforms) — smaller n unless --full
                if o.full || n <= 128 {
                    let m = budget(alpha, n);
                    let (_, _, rel) = gen_factor(&x, m, 1);
                    e_gen.push(rel);
                    let r_gen = (alpha as f64 * logn).round() as usize;
                    lr_gen
                        .push((baselines::lowrank_error_general(&x, r_gen) / x.fro_norm_sq()).sqrt());
                }
            }
            emit(&mut rows, format!("fig5/sym-indefinite/n={n}/proposed"), alpha as f64, &e_indef);
            emit(&mut rows, format!("fig5/sym-indefinite/n={n}/lowrank"), alpha as f64, &lr_sym_indef);
            emit(&mut rows, format!("fig5/sym-psd/n={n}/proposed"), alpha as f64, &e_psd);
            emit(&mut rows, format!("fig5/sym-psd/n={n}/lowrank"), alpha as f64, &lr_sym_psd);
            if !e_gen.is_empty() {
                emit(&mut rows, format!("fig5/general/n={n}/proposed"), alpha as f64, &e_gen);
                emit(&mut rows, format!("fig5/general/n={n}/lowrank"), alpha as f64, &lr_gen);
            }
        }
    }
    rows
}

/// Random plan of `g` G-transforms (timing only — apply cost does not
/// depend on the values).
pub fn random_gplan(n: usize, g: usize, rng: &mut Rng64) -> GChain {
    let mut ch = GChain::identity(n);
    for _ in 0..g {
        let i = rng.below(n - 1);
        let j = i + 1 + rng.below(n - 1 - i);
        let th = rng.uniform_in(0.0, std::f64::consts::TAU);
        let kind = if rng.bernoulli(0.5) { GKind::Rotation } else { GKind::Reflection };
        ch.transforms.push(GTransform::new(i, j, th.cos(), th.sin(), kind));
    }
    ch
}

/// Random T-plan of `m` transforms.
pub fn random_tplan(n: usize, m: usize, rng: &mut Rng64) -> TChain {
    let mut ch = TChain::identity(n);
    for _ in 0..m {
        let i = rng.below(n - 1);
        let j = i + 1 + rng.below(n - 1 - i);
        ch.transforms.push(match rng.below(3) {
            0 => TTransform::Scaling { i, a: 1.0 + 0.1 * rng.randn() },
            1 => TTransform::UpperShear { i, j, a: 0.2 * rng.randn() },
            _ => TTransform::LowerShear { i, j, a: 0.2 * rng.randn() },
        });
    }
    ch
}

/// **Fig. 6 (supp)** — apply-time speedup of the factored transforms vs
/// dense matrix–vector multiplication for the Fig.-2 graphs (at the
/// *original* sizes — timing does not need the factorization itself, only
/// its shape): FLOP-count ratio and measured wall-clock ratio, f32,
/// single vector, no parallelism (paper: C vs BLAS SGEMV on one core).
pub fn fig6(o: &FigOptions) -> Vec<Row> {
    println!("# Fig 6 — fast-apply speedup vs dense mat-vec (FLOPs and measured)");
    let alpha = *o.alphas.first().unwrap_or(&2);
    let mut rows = Vec::new();
    for w in RealWorldGraph::all() {
        let (n, _) = w.dimensions();
        let n = if o.full { n } else { ((n as f64 * o.scale) as usize).max(64) };
        let g = budget(alpha, n);
        let mut rng = Rng64::new(o.seed ^ 0xF16_6);
        let gplan = random_gplan(n, g, &mut rng).to_plan();
        let tplan = random_tplan(n, g, &mut rng).to_plan();
        // dense operator and a signal
        let dense: Vec<f32> = (0..n * n).map(|_| rng.randn() as f32).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        let mut y = vec![0f32; n];
        let t_dense = crate::bench_util::bench(&format!("dense n={n}"), 5, 0.02, || {
            // straightforward f32 gemv
            for (r, yr) in y.iter_mut().enumerate() {
                let row = &dense[r * n..(r + 1) * n];
                let mut acc = 0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                *yr = acc;
            }
            y[0]
        });
        let mut block =
            crate::transforms::SignalBlock::from_signals(&[x.clone()]).expect("uniform batch");
        let t_g = crate::bench_util::bench(&format!("gchain n={n} g={g}"), 5, 0.02, || {
            crate::transforms::apply_gchain_batch_f32(&gplan, &mut block);
            block.data[0]
        });
        let mut block2 =
            crate::transforms::SignalBlock::from_signals(&[x.clone()]).expect("uniform batch");
        let t_t = crate::bench_util::bench(&format!("tchain n={n} m={g}"), 5, 0.02, || {
            crate::transforms::apply_tchain_batch_f32(&tplan, &mut block2, false);
            block2.data[0]
        });
        let flop_ratio_g = (2.0 * (n * n) as f64) / (6.0 * g as f64);
        let flop_ratio_t = (2.0 * (n * n) as f64) / (2.0 * g as f64);
        let meas_g = t_dense.min_s / t_g.min_s;
        let meas_t = t_dense.min_s / t_t.min_s;
        println!(
            "fig6/{:<14} n={n:<6} g={g:<8} flopx(G)={flop_ratio_g:<8.2} measured(G)={meas_g:<8.2} flopx(T)={flop_ratio_t:<8.2} measured(T)={meas_t:<8.2}",
            w.name()
        );
        rows.push(Row { label: format!("fig6/{}/G-flop", w.name()), x: n as f64, mean: flop_ratio_g, std: 0.0 });
        rows.push(Row { label: format!("fig6/{}/G-measured", w.name()), x: n as f64, mean: meas_g, std: 0.0 });
        rows.push(Row { label: format!("fig6/{}/T-flop", w.name()), x: n as f64, mean: flop_ratio_t, std: 0.0 });
        rows.push(Row { label: format!("fig6/{}/T-measured", w.name()), x: n as f64, mean: meas_t, std: 0.0 });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_rule() {
        assert_eq!(budget(1, 128), 128 * 7);
        assert_eq!(budget(2, 256), 2 * 256 * 8);
    }

    fn tiny_opts() -> FigOptions {
        FigOptions {
            scale: 0.02,
            reals: 1,
            sizes: vec![16],
            alphas: vec![1],
            full: false,
            seed: 7,
            sweeps: 1,
        }
    }

    #[test]
    fn fig1_tiny_runs_and_is_sane() {
        let rows = fig1(&tiny_opts());
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.mean.is_finite() && r.mean >= 0.0, "{r:?}");
        }
    }

    #[test]
    fn fig5_tiny_proposed_beats_or_ties_lowrank_somewhere() {
        let rows = fig5(&tiny_opts());
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.mean.is_finite());
        }
    }

    #[test]
    fn fig6_tiny_reports_positive_ratios() {
        let rows = fig6(&tiny_opts());
        for r in &rows {
            assert!(r.mean > 0.0, "{r:?}");
        }
    }
}
