//! # fastes — fast approximate eigenspaces & fast graph Fourier transforms
//!
//! A production-oriented reproduction of
//! *"Constructing fast approximate eigenspaces with application to the fast
//! graph Fourier transforms"* (C. Rusu, L. Rosasco — IEEE TSP 2021).
//!
//! The library factors the eigenspace of a symmetric matrix `S` (or a
//! general diagonalizable matrix `C`) into a fixed number of 2×2-supported
//! butterflies:
//!
//! * **G-transforms** — extended orthonormal Givens transformations
//!   (rotations *and* reflections), giving `S ≈ Ū diag(s̄) Ūᵀ` with
//!   `Ū = G_g … G_1` and `O(g)` matrix–vector multiplication;
//! * **T-transforms** — scalings and shears, giving
//!   `C ≈ T̄ diag(c̄) T̄⁻¹` with `T̄ = T_m … T_1` and trivially invertible
//!   factors.
//!
//! Both factorizations are computed by [`factor`]'s implementation of the
//! paper's Algorithm 1 (closed-form locally-optimal initialization +
//! iterative polishing), on top of a self-contained dense linear-algebra
//! substrate in [`linalg`] (no LAPACK/BLAS dependency).
//!
//! The flagship application, the **fast graph Fourier transform**, lives in
//! [`graphs`] (graph generators + Laplacians) and is served end-to-end by
//! the coordinator in [`serve`], which executes either the native
//! rust butterfly fast-path from [`transforms`] or an AOT-compiled
//! JAX/Pallas artifact through the PJRT runtime in [`runtime`].
//!
//! ## One execution surface: `plan::FastOperator`
//!
//! Every factored operator — a raw chain, a compiled plan, the native
//! serve backend — implements [`plan::FastOperator`]: direction-
//! polymorphic apply ([`plan::Direction::Forward`] /
//! [`plan::Direction::Adjoint`]) with the engine chosen **per call** by a
//! [`plan::ExecPolicy`] (`Seq` / `Spawn` / `Pool`). Plans are built with
//! `Plan::from(&chain).schedule(opts).fuse(opts).build()` and persist as
//! versioned `.fastplan` artifacts ([`plan::Plan::save`] /
//! [`plan::Plan::load`]), so `fastes factor --save-plan` output feeds
//! `fastes serve --plan` without refactorizing.
//!
//! ## Level-scheduled, fused, pooled execution
//!
//! The `O(g)` apply is *sequential* as written (`G_1`, then `G_2`, …), but
//! butterflies with disjoint `(i, j)` supports commute.
//! [`transforms::schedule`] compiles any chain into **conflict-free
//! layers** (greedy list scheduling over the coordinate-conflict DAG),
//! **fuses** consecutive layers into flat per-direction superstage
//! streams (contiguous structure-of-arrays coefficients in `f32` and
//! `f64`), and executes the compiled plan ([`transforms::CompiledPlan`])
//! **cache-blocked** on a **persistent worker pool**
//! ([`transforms::pool`]): parked workers claim `(n, tile_cols)` column
//! tiles from an atomic cursor and stream each tile through the whole
//! fused plan while it is L1/L2-resident — no thread spawns on the
//! request path. The per-stage inner loops run on hand-vectorized
//! AVX-512/AVX2/NEON kernels with runtime ISA dispatch and a scalar
//! fallback ([`transforms::simd`]; `FASTES_KERNEL` / `--kernel`
//! override), over tiles packed into contiguous per-thread scratch. The
//! reordering only permutes commuting stages and every SIMD lane
//! performs the exact scalar operation sequence (no FMA), so every
//! engine × kernel combination is **bitwise identical** to the
//! sequential scalar apply — enforced by the cross-engine conformance
//! suite (`rust/tests/conformance.rs`). The serving backend
//! ([`serve::NativeGftBackend`]) runs pooled by default (`fastes serve
//! --exec pool`), and `fastes schedule` / `fastes bench --json` report
//! schedule shapes, measured speedups and the dispatched `kernel_isa`.
//!
//! ## Layering (three-layer AOT architecture)
//!
//! ```text
//! L3  rust   — this crate: factorization engine, coordinator, serving
//! L2  jax    — python/compile/model.py: GFT compute graph (build-time)
//! L1  pallas — python/compile/kernels/butterfly.py: butterfly kernel
//! ```
//!
//! Python runs only at build time (`make artifacts`); the rust binary is
//! self-contained afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fastes::linalg::{Mat, Rng64};
//! use fastes::factor::symmetric::{SymFactorizer, SymOptions};
//!
//! let mut rng = Rng64::new(7);
//! let x = Mat::randn(64, 64, &mut rng);
//! let s = &x + &x.transpose(); // symmetric target
//! let opts = SymOptions::default();
//! let fac = SymFactorizer::new(&s, 64 * 6, opts).run();
//! println!("relative error {}", fac.relative_error(&s));
//! ```

pub mod baselines;
pub mod bench_util;
pub mod cli;
pub mod factor;
pub mod graphs;
pub mod linalg;
pub mod ops;
pub mod plan;
pub mod prop;
pub mod runtime;
pub mod serve;
pub mod transforms;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
