//! Rotation-only greedy factorization with the eigenvalue-blind score
//! `𝒜_ij = γ_ij` (paper Remark 1) — our stand-in for the multiresolution
//! greedy Givens construction of Kondor et al. (2014). Unlike
//! [`super::jacobi`], the pair selection accounts for the diagonal
//! disparity (`γ_ij → S_ii − S_jj` when the off-diagonal is small), and
//! unlike the proposed method it never uses reflections or eigenvalue
//! pairing.
//!
//! Uses the same incremental row-maxima bookkeeping as the other greedy
//! paths: a conjugation at `(p, q)` only re-scores pairs touching `p` or
//! `q`, so each step is `O(n)` amortized instead of an `O(n²)` rescan.

use crate::linalg::{sym2_eig, Mat};
use crate::transforms::{GChain, GTransform};

use super::jacobi::JacobiResult;

/// The off-diagonal-driven part of `γ_ij` (paper eq. (16)):
/// `½(√((S_ii−S_jj)² + 4S_ij²) − |S_ii − S_jj|) = 2S_ij²/(rad + |d|)`.
///
/// The raw `γ` keeps a positive diagonal-disparity term even for
/// already-diagonal pairs, so a greedy driven by it re-selects the same
/// pair with identity transforms forever (the stall is visible as a
/// flat accuracy-vs-g curve). Removing the `|d|` offset keeps the
/// γ-characteristic ranking — `≈ |S_ij|` when the off-diagonal dominates,
/// `≈ S_ij²/|S_ii−S_jj|` when the disparity dominates (the two regimes of
/// Remark 1) — while vanishing exactly when there is nothing to rotate.
#[inline]
fn gamma(w: &Mat, i: usize, j: usize) -> f64 {
    let d = w[(i, i)] - w[(j, j)];
    let off = w[(i, j)];
    let rad = (d * d + 4.0 * off * off).sqrt();
    0.5 * (rad - d.abs())
}

/// Run `g` greedy rotation-only steps with the `γ` score.
pub fn greedy_givens(s: &Mat, g: usize) -> JacobiResult {
    let n = s.rows();
    let mut w = s.clone();
    let mut picked: Vec<GTransform> = Vec::with_capacity(g);
    if n < 2 {
        return JacobiResult { chain: GChain { n, transforms: picked }, spectrum: w.diag(), objective: 0.0 };
    }
    // row-maxima bookkeeping over the γ score
    let mut best_j = vec![usize::MAX; n];
    let mut best_v = vec![f64::NEG_INFINITY; n];
    let rescan = |w: &Mat, i: usize, best_j: &mut [usize], best_v: &mut [f64]| {
        let mut bj = usize::MAX;
        let mut bv = f64::NEG_INFINITY;
        for j in (i + 1)..n {
            let v = gamma(w, i, j);
            if v > bv {
                bv = v;
                bj = j;
            }
        }
        best_j[i] = bj;
        best_v[i] = bv;
    };
    for i in 0..n - 1 {
        rescan(&w, i, &mut best_j, &mut best_v);
    }

    for _ in 0..g {
        let mut bi = 0;
        for i in 1..n - 1 {
            if best_v[i] > best_v[bi] {
                bi = i;
            }
        }
        let (i, j, score) = (bi, best_j[bi], best_v[bi]);
        if j == usize::MAX || score <= 1e-14 * (1.0 + w.max_abs()) {
            break;
        }
        let e = sym2_eig(w[(i, i)], w[(i, j)], w[(j, j)]);
        let v = [[e.v1[0], e.v2[0]], [e.v1[1], e.v2[1]]];
        let t = GTransform::from_block(i, j, v);
        t.conjugate_t(&mut w);
        picked.push(t);
        // refresh bookkeeping for pairs touching (i, j)
        for r in 0..n - 1 {
            if r == i || r == j {
                rescan(&w, r, &mut best_j, &mut best_v);
            } else {
                let mut need_rescan = false;
                for &t2 in &[i, j] {
                    if t2 > r {
                        let val = gamma(&w, r, t2);
                        if val > best_v[r] {
                            best_v[r] = val;
                            best_j[r] = t2;
                        } else if best_j[r] == t2 {
                            need_rescan = true;
                        }
                    }
                }
                if need_rescan {
                    rescan(&w, r, &mut best_j, &mut best_v);
                }
            }
        }
    }
    picked.reverse();
    let chain = GChain { n, transforms: picked };
    let spectrum = w.diag();
    let objective = crate::transforms::error::off_diagonal_sq(&w);
    JacobiResult { chain, spectrum, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng64::new(seed);
        let x = Mat::randn(n, n, &mut rng);
        &x + &x.transpose()
    }

    #[test]
    fn improves_with_budget() {
        let s = random_sym(9, 511);
        let r1 = greedy_givens(&s, 8);
        let r2 = greedy_givens(&s, 40);
        assert!(r2.objective <= r1.objective * (1.0 + 1e-12));
    }

    #[test]
    fn objective_consistent() {
        let s = random_sym(7, 512);
        let r = greedy_givens(&s, 12);
        let direct = r.chain.objective(&s, &r.spectrum);
        assert!((direct - r.objective).abs() < 1e-8 * (1.0 + direct));
    }

    #[test]
    fn gamma_is_nonnegative() {
        let s = random_sym(6, 513);
        for i in 0..5 {
            for j in (i + 1)..6 {
                assert!(gamma(&s, i, j) >= 0.0);
            }
        }
    }

    #[test]
    fn incremental_matches_exhaustive_selection_quality() {
        // the bookkeeping must not degrade the greedy: objective within a
        // whisker of a brute-force O(n²)-per-step variant
        let s = random_sym(10, 514);
        let fast = greedy_givens(&s, 25);
        // brute-force reference
        let n = 10;
        let mut w = s.clone();
        for _ in 0..25 {
            let mut best = (0usize, 1usize, f64::NEG_INFINITY);
            for i in 0..n - 1 {
                for j in (i + 1)..n {
                    let v = gamma(&w, i, j);
                    if v > best.2 {
                        best = (i, j, v);
                    }
                }
            }
            let e = sym2_eig(w[(best.0, best.0)], w[(best.0, best.1)], w[(best.1, best.1)]);
            let v = [[e.v1[0], e.v2[0]], [e.v1[1], e.v2[1]]];
            GTransform::from_block(best.0, best.1, v).conjugate_t(&mut w);
        }
        let brute_obj = w.off_diag_sq();
        assert!(
            (fast.objective - brute_obj).abs() < 1e-6 * (1.0 + brute_obj),
            "fast {} vs brute {brute_obj}",
            fast.objective
        );
    }
}
