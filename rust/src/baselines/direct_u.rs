//! Factoring a *known* orthonormal eigenspace directly — the approach of
//! Rusu & Rosasco (2019) that the paper compares against in Fig. 4.
//!
//! Given `U` (from a precomputed eigendecomposition), greedily build
//! `Ū = G_g … G_1` minimizing `‖(U − Ū) diag(w)‖²_F` for a weight vector
//! `w` (all-ones = plain eigenspace approximation; `w = λ` = the weighted
//! `U_γ` variant). Each step maximizes the alignment trace
//! `tr(diag(w²) Ūᵀ U)` by a one-sided 2×2 Procrustes (polar factor) on
//! the working matrix `M = U diag(w²) Ū'ᵀ`.

use crate::linalg::{procrustes2_rotation, Mat};
use crate::transforms::{GChain, GTransform};

/// Result of a direct-eigenspace factorization.
#[derive(Clone, Debug)]
pub struct DirectUResult {
    /// The factored orthonormal approximation `Ū`.
    pub chain: GChain,
    /// Final weighted alignment `tr(diag(w²) Ūᵀ U)` (higher is better;
    /// equals `Σ w²` at perfect recovery).
    pub alignment: f64,
}

impl DirectUResult {
    /// `‖(U − Ū) diag(w)‖²_F = 2 Σw² − 2·alignment` (for orthonormal
    /// `U`, `Ū`).
    pub fn weighted_error_sq(&self, weights: &[f64]) -> f64 {
        let total: f64 = weights.iter().map(|w| w * w).sum();
        (2.0 * total - 2.0 * self.alignment).max(0.0)
    }
}

/// Greedily factor orthonormal `u` into `g` G-transforms, minimizing the
/// `w`-weighted Frobenius error.
pub fn factor_orthonormal(u: &Mat, weights: &[f64], g: usize) -> DirectUResult {
    let n = u.rows();
    assert!(u.is_square());
    assert_eq!(weights.len(), n);
    // M = U diag(w²) Ū'ᵀ, Ū' the chain so far (initially I).
    let mut m = u.clone();
    for (j, &w) in weights.iter().enumerate() {
        m.scale_col(j, w * w);
    }
    // tr(diag(w²)ŪᵀU) = Σ_k w_k² (ŪᵀU)_kk; define M = U·diag(w²) so the
    // target is tr(Ūᵀ M) = ⟨Ū, M⟩. Choose each new factor G (prepended to
    // Ū) to maximize ⟨G Ū', M⟩ = ⟨G, W⟩ with W := M Ū'ᵀ (maintained by
    // right-multiplying M with Gᵀ). The per-pair gain is the polar
    // alignment of the 2×2 block; right-multiplying by Gᵀ only touches
    // columns (i, j), so row-maxima bookkeeping keeps each step O(n)
    // amortized.
    let pair_gain = |m: &Mat, i: usize, j: usize| -> f64 {
        let block = [[m[(i, i)], m[(i, j)]], [m[(j, i)], m[(j, j)]]];
        let gblk = procrustes2_rotation(block, true);
        let tr_new = gblk[0][0] * block[0][0]
            + gblk[0][1] * block[0][1]
            + gblk[1][0] * block[1][0]
            + gblk[1][1] * block[1][1];
        tr_new - (block[0][0] + block[1][1])
    };
    let mut best_j = vec![usize::MAX; n];
    let mut best_v = vec![f64::NEG_INFINITY; n];
    let rescan = |m: &Mat, i: usize, best_j: &mut [usize], best_v: &mut [f64]| {
        let mut bj = usize::MAX;
        let mut bv = f64::NEG_INFINITY;
        for j in (i + 1)..n {
            let v = pair_gain(m, i, j);
            if v > bv {
                bv = v;
                bj = j;
            }
        }
        best_j[i] = bj;
        best_v[i] = bv;
    };
    for i in 0..n.saturating_sub(1) {
        rescan(&m, i, &mut best_j, &mut best_v);
    }
    let mut picked: Vec<GTransform> = Vec::with_capacity(g);
    for _ in 0..g {
        let mut bi = 0;
        for r in 1..n.saturating_sub(1) {
            if best_v[r] > best_v[bi] {
                bi = r;
            }
        }
        let (i, j, gain) = (bi, best_j[bi], best_v[bi]);
        if j == usize::MAX || gain <= 1e-14 * (1.0 + m.max_abs()) {
            break;
        }
        let block = [[m[(i, i)], m[(i, j)]], [m[(j, i)], m[(j, j)]]];
        let t = GTransform::from_block(i, j, procrustes2_rotation(block, true));
        t.apply_right_t(&mut m);
        picked.push(t);
        for r in 0..n.saturating_sub(1) {
            if r == i || r == j {
                rescan(&m, r, &mut best_j, &mut best_v);
            } else {
                let mut need_rescan = false;
                for &t2 in &[i, j] {
                    if t2 > r {
                        let v = pair_gain(&m, r, t2);
                        if v > best_v[r] {
                            best_v[r] = v;
                            best_j[r] = t2;
                        } else if best_j[r] == t2 {
                            need_rescan = true;
                        }
                    }
                }
                if need_rescan {
                    rescan(&m, r, &mut best_j, &mut best_v);
                }
            }
        }
    }
    // Ū = G_last … G_first: the first picked factor is the innermost
    // (applied first to a vector) — wait: we appended on the LEFT each
    // time, so the last picked is the leftmost G_g and the first picked
    // is G_1, which the chain stores first. No reversal needed.
    let chain = GChain { n, transforms: picked };
    let alignment: f64 = {
        // tr(Ūᵀ M_original) with M_original = U diag(w²): recompute
        let mut m2 = u.clone();
        for (j, &w) in weights.iter().enumerate() {
            m2.scale_col(j, w * w);
        }
        let ubar = chain.to_dense();
        ubar.fro_dot(&m2)
    };
    DirectUResult { chain, alignment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, Rng64};

    fn random_orthonormal(n: usize, seed: u64) -> Mat {
        let mut rng = Rng64::new(seed);
        let x = Mat::randn(n, n, &mut rng);
        let s = &x + &x.transpose();
        eigh(&s).vectors
    }

    #[test]
    fn alignment_increases_with_budget() {
        let u = random_orthonormal(8, 521);
        let w = vec![1.0; 8];
        let mut prev = f64::NEG_INFINITY;
        for g in [2, 8, 28, 84] {
            let r = factor_orthonormal(&u, &w, g);
            assert!(r.alignment >= prev - 1e-10, "g={g}");
            prev = r.alignment;
        }
    }

    #[test]
    fn exact_recovery_with_enough_factors() {
        // an orthonormal U needs at most n(n−1)/2 G-transforms
        let u = random_orthonormal(6, 522);
        let w = vec![1.0; 6];
        let r = factor_orthonormal(&u, &w, 60);
        let err = r.weighted_error_sq(&w);
        assert!(err < 1e-12, "error {err}");
        // dense check
        let dist = r.chain.to_dense().fro_dist_sq(&u);
        assert!(dist < 1e-12, "dense dist {dist}");
    }

    #[test]
    fn weighted_error_formula_matches_dense() {
        let u = random_orthonormal(7, 523);
        let w: Vec<f64> = (0..7).map(|i| 1.0 + i as f64 * 0.3).collect();
        let r = factor_orthonormal(&u, &w, 10);
        let formula = r.weighted_error_sq(&w);
        // dense: ‖(U − Ū)diag(w)‖²
        let mut d = &u - &r.chain.to_dense();
        for (j, &wj) in w.iter().enumerate() {
            d.scale_col(j, wj);
        }
        assert!(
            (formula - d.fro_norm_sq()).abs() < 1e-7 * (1.0 + formula),
            "{formula} vs {}",
            d.fro_norm_sq()
        );
    }

    #[test]
    fn weights_bias_the_approximation() {
        // heavily weighting the first column should approximate it better
        let u = random_orthonormal(10, 524);
        let mut w = vec![0.1; 10];
        w[0] = 10.0;
        let r = factor_orthonormal(&u, &w, 12);
        let ubar = r.chain.to_dense();
        let col_err = |m: &Mat, j: usize| -> f64 {
            (0..10).map(|i| (m[(i, j)] - u[(i, j)]) * (m[(i, j)] - u[(i, j)])).sum()
        };
        let e0 = col_err(&ubar, 0);
        let eother: f64 = (1..10).map(|j| col_err(&ubar, j)).sum::<f64>() / 9.0;
        assert!(e0 < eother, "weighted column error {e0} vs avg {eother}");
    }
}
