//! Baseline methods the paper compares against (Figs. 2–5).
//!
//! * [`jacobi`] — truncated Jacobi diagonalization (Le Magoarou, Gribonval
//!   & Tremblay 2018): classic max-off-diagonal Givens *rotations* only.
//! * [`greedy_givens`] — rotation-only greedy with the eigenvalue-blind
//!   score `𝒜 = γ_ij` (the paper's Remark-1 reduction, standing in for
//!   the multiresolution greedy of Kondor et al. 2014).
//! * [`direct_u`] — factoring a *known* orthonormal eigenspace `U`
//!   directly (Rusu & Rosasco 2019), optionally weighted by the spectrum
//!   (the `U_γ` variant of Fig. 4); greedy one-sided 2×2 Procrustes.
//! * [`lowrank`] — best rank-`r` approximation at a matched flop budget
//!   (Fig. 5's black curves): truncated eigendecomposition for symmetric
//!   inputs, truncated SVD for general inputs.

mod direct_u;
mod greedy_givens;
mod jacobi;
mod lowrank;

pub use direct_u::{factor_orthonormal, DirectUResult};
pub use greedy_givens::greedy_givens;
pub use jacobi::{truncated_jacobi, JacobiResult};
pub use lowrank::{lowrank_error_general, lowrank_error_symmetric, svd_values};
