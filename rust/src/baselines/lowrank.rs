//! Rank-`r` approximation baselines at matched flop budgets (Fig. 5).
//!
//! A rank-`r` factorization costs `2rn` flops per matrix–vector product,
//! so Fig. 5 matches `r = 3·α·log₂n` against `g = α·n·log₂n` G-transforms
//! (6 flops each) and `r = α·log₂n` against the same number of
//! T-transforms (2 flops each).

use crate::linalg::{eigh, Mat};

/// Squared singular values of a general square matrix, descending
/// (computed as the eigenvalues of `AᵀA`).
pub fn svd_values(a: &Mat) -> Vec<f64> {
    let ata = a.transpose().matmul(a);
    eigh(&ata).values.into_iter().map(|v| v.max(0.0)).collect()
}

/// `‖S − S_r‖²_F` of the best rank-`r` approximation of a *symmetric*
/// matrix: keep the `r` eigenvalues of largest magnitude.
pub fn lowrank_error_symmetric(s: &Mat, r: usize) -> f64 {
    let mut vals = eigh(s).values;
    // sort by |λ| descending; discard the r largest
    vals.sort_by(|a, b| b.abs().partial_cmp(&a.abs()).unwrap());
    vals.iter().skip(r).map(|v| v * v).sum()
}

/// `‖C − C_r‖²_F` of the best rank-`r` approximation of a general matrix
/// (Eckart–Young): the sum of the discarded squared singular values.
pub fn lowrank_error_general(c: &Mat, r: usize) -> f64 {
    svd_values(c).into_iter().skip(r).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;

    #[test]
    fn full_rank_is_exact() {
        let mut rng = Rng64::new(531);
        let x = Mat::randn(6, 6, &mut rng);
        let s = &x + &x.transpose();
        assert!(lowrank_error_symmetric(&s, 6) < 1e-9);
        assert!(lowrank_error_general(&x, 6) < 1e-9 * x.fro_norm_sq());
    }

    #[test]
    fn zero_rank_is_full_norm() {
        let mut rng = Rng64::new(532);
        let x = Mat::randn(5, 5, &mut rng);
        let s = &x + &x.transpose();
        assert!((lowrank_error_symmetric(&s, 0) - s.fro_norm_sq()).abs() < 1e-8);
        assert!((lowrank_error_general(&x, 0) - x.fro_norm_sq()).abs() < 1e-8);
    }

    #[test]
    fn monotone_in_rank() {
        let mut rng = Rng64::new(533);
        let x = Mat::randn(8, 8, &mut rng);
        let s = &x + &x.transpose();
        let mut prev = f64::INFINITY;
        for r in 0..=8 {
            let e = lowrank_error_symmetric(&s, r);
            assert!(e <= prev + 1e-10);
            prev = e;
        }
    }

    #[test]
    fn svd_values_match_known() {
        // diag(3, -4) has singular values 4, 3
        let a = Mat::from_diag(&[3.0, -4.0]);
        let sv = svd_values(&a);
        assert!((sv[0] - 16.0).abs() < 1e-10);
        assert!((sv[1] - 9.0).abs() < 1e-10);
    }

    #[test]
    fn eckart_young_dominates_random_projection() {
        // best rank-1 error must be ≤ error of any specific rank-1 approx
        let mut rng = Rng64::new(534);
        let x = Mat::randn(5, 5, &mut rng);
        let best = lowrank_error_general(&x, 1);
        for _ in 0..10 {
            let u: Vec<f64> = (0..5).map(|_| rng.randn()).collect();
            let unorm: f64 = u.iter().map(|v| v * v).sum::<f64>().sqrt();
            let u: Vec<f64> = u.iter().map(|v| v / unorm).collect();
            // projection of each column on u
            let mut approx = Mat::zeros(5, 5);
            for j in 0..5 {
                let col = x.col(j);
                let dot: f64 = col.iter().zip(u.iter()).map(|(a, b)| a * b).sum();
                for i in 0..5 {
                    approx[(i, j)] = dot * u[i];
                }
            }
            assert!(best <= x.fro_dist_sq(&approx) + 1e-9);
        }
    }
}
