//! Truncated Jacobi diagonalization — the fast-GFT baseline of
//! Le Magoarou, Gribonval & Tremblay (2018).
//!
//! Repeatedly zero the largest-magnitude off-diagonal entry with a plain
//! Givens rotation, stopping after a fixed budget of `g` rotations. The
//! eigenvalue estimate is the diagonal of the final working matrix (which
//! is also the Lemma-1 optimum for the produced `Ū`).

use crate::linalg::{sym2_eig, Mat};
use crate::transforms::{GChain, GTransform};

/// Result of a truncated Jacobi run.
#[derive(Clone, Debug)]
pub struct JacobiResult {
    /// The accumulated rotation chain `Ū` (application order).
    pub chain: GChain,
    /// Diagonal of the final working matrix (the spectrum estimate).
    pub spectrum: Vec<f64>,
    /// `‖S − Ū diag(s̄) Ūᵀ‖²_F` = off-diagonal energy of the final
    /// working matrix.
    pub objective: f64,
}

/// Run `g` Jacobi steps on symmetric `s`.
pub fn truncated_jacobi(s: &Mat, g: usize) -> JacobiResult {
    let n = s.rows();
    let mut w = s.clone();
    // row-maxima bookkeeping: best |off-diagonal| per row
    let mut best_j = vec![0usize; n];
    let mut best_v = vec![f64::NEG_INFINITY; n];
    let rescan = |w: &Mat, i: usize, best_j: &mut [usize], best_v: &mut [f64]| {
        let mut bj = usize::MAX;
        let mut bv = f64::NEG_INFINITY;
        for j in (i + 1)..n {
            if w[(i, j)].abs() > bv {
                bv = w[(i, j)].abs();
                bj = j;
            }
        }
        best_j[i] = bj;
        best_v[i] = bv;
    };
    for i in 0..n {
        rescan(&w, i, &mut best_j, &mut best_v);
    }

    let mut picked: Vec<GTransform> = Vec::with_capacity(g);
    for _ in 0..g {
        // global max |off-diagonal|
        let mut bi = 0;
        for i in 1..n {
            if best_v[i] > best_v[bi] {
                bi = i;
            }
        }
        let (i, j) = (bi, best_j[bi]);
        if j == usize::MAX || best_v[bi] <= 1e-300 {
            break; // numerically diagonal
        }
        // rotation diagonalizing the 2×2 block: columns of the eigvec
        // matrix; install V so that Vᵀ S_b V = D
        let e = sym2_eig(w[(i, i)], w[(i, j)], w[(j, j)]);
        let v = [[e.v1[0], e.v2[0]], [e.v1[1], e.v2[1]]];
        let t = GTransform::from_block(i, j, v);
        t.conjugate_t(&mut w);
        picked.push(t);
        // refresh bookkeeping
        for r in 0..n {
            if r == i || r == j {
                rescan(&w, r, &mut best_j, &mut best_v);
            } else {
                for &t2 in &[i, j] {
                    if t2 > r {
                        let val = w[(r, t2)].abs();
                        if val > best_v[r] {
                            best_v[r] = val;
                            best_j[r] = t2;
                        } else if best_j[r] == t2 {
                            rescan(&w, r, &mut best_j, &mut best_v);
                        }
                    }
                }
            }
        }
    }
    picked.reverse(); // application order: first-picked acts last on S…
    let chain = GChain { n, transforms: picked };
    let spectrum = w.diag();
    // off-diagonal energy == the shared diagonalization residual at the
    // working matrix's own diagonal (bitwise — pinned in transforms::error)
    let objective = crate::transforms::error::off_diagonal_sq(&w);
    JacobiResult { chain, spectrum, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;

    fn random_sym(n: usize, seed: u64) -> Mat {
        let mut rng = Rng64::new(seed);
        let x = Mat::randn(n, n, &mut rng);
        &x + &x.transpose()
    }

    #[test]
    fn objective_matches_chain_reconstruction() {
        let s = random_sym(8, 501);
        let r = truncated_jacobi(&s, 20);
        let direct = r.chain.objective(&s, &r.spectrum);
        assert!(
            (direct - r.objective).abs() < 1e-8 * (1.0 + direct),
            "{direct} vs {}",
            r.objective
        );
    }

    #[test]
    fn off_diagonal_energy_decreases() {
        let s = random_sym(10, 502);
        let mut prev = f64::INFINITY;
        for g in [5, 15, 45, 90] {
            let r = truncated_jacobi(&s, g);
            assert!(r.objective <= prev * (1.0 + 1e-12), "g={g}: {} > {prev}", r.objective);
            prev = r.objective;
        }
    }

    #[test]
    fn converges_to_diagonal() {
        let s = random_sym(6, 503);
        let r = truncated_jacobi(&s, 200);
        assert!(r.objective < 1e-18 * s.fro_norm_sq(), "off² = {}", r.objective);
        // spectrum should match eigh
        let mut spec = r.spectrum.clone();
        spec.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let e = crate::linalg::eigh(&s);
        for (a, b) in spec.iter().zip(e.values.iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn rotations_only() {
        use crate::transforms::GKind;
        let s = random_sym(7, 504);
        let r = truncated_jacobi(&s, 30);
        for t in &r.chain.transforms {
            assert_eq!(t.kind, GKind::Rotation, "Jacobi must not use reflections");
        }
    }
}
