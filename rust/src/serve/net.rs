//! Blocking TCP front-end for the serving coordinator.
//!
//! # Wire protocol
//!
//! Length-prefixed JSON: every frame is a little-endian `u32` byte count
//! followed by exactly that many bytes of UTF-8 JSON (one object per
//! frame, requests and replies alike). Connections are persistent — a
//! client sends any number of request frames and reads one reply frame
//! per request, in order.
//!
//! Requests (`op` selects the verb):
//!
//! ```text
//! {"op":"forward","signal":[..],            // analysis GFT (aka "submit")
//!  "plan":"<16-hex checksum>",              // optional registry route
//!  "priority":"interactive"|"batch",        // optional, default interactive
//!  "deadline_ms":N}                         // optional relative deadline
//! {"op":"adjoint","signal":[..], ...}       // synthesis GFT
//! {"op":"filter","signal":[..],             // fused filter Ū diag(h) Ūᵀ x:
//!  "response":[..]}                         //   explicit diagonal h, or
//! {"op":"filter","signal":[..],             //   an analytic kernel
//!  "kernel":"heat","param":0.5}             //   evaluated on the plan's s̄
//! {"op":"wavelet","signal":[..],"scales":J} // Hammond bank, J+1 bands
//! {"op":"topk","signal":[..],               // sparse top-k of Ūᵀ x
//!  "k":K,"threshold":T}                     //   (k and/or threshold)
//! {"op":"metrics"}                          // serving + registry counters
//! {"op":"upload_plan","bytes":"<hex>",      // .fastplan bytes, hex-encoded
//!  "default":true|false}                    // true = atomic hot swap
//! {"op":"refactor","matrix":[..n·n..],      // drifted S′ (row-major, f64):
//!  "from":"<16-hex checksum>",              //   optional donor (default:
//!  "budget":E,"max_g":G,                    //   the default plan), optional
//!  "sync":true|false}                       //   growth budget; sync waits
//! ```
//!
//! The spectral ops (`filter`/`wavelet`/`topk`) need a registry-routed
//! plan; kernel filters and wavelets additionally need the plan to carry
//! its spectrum (a version-2 `.fastplan`). A wavelet reply's `signal` is
//! the band-major stack `[band0 | band1 | … | bandJ]` of `(J+1)·n` values
//! (band 0 = scaling function).
//!
//! The `refactor` op hands the drifted matrix to the background
//! [`RefactorWorker`]: it warm-starts from the donor plan's chain,
//! re-certifies against the drifted matrix, and atomically swaps the
//! registry default while in-flight batches drain on the old plan —
//! unless the new certificate misses the server's `--max-error` budget,
//! in which case the swap is refused and the resident plan stays.
//! `"sync":true` waits for the outcome
//! (`{"ok":true,"swapped":B,"checksum":..,"old_checksum":..,
//! "rel_err":..,"g":..,"sweeps":..,"refused":MSG?}`); the default
//! replies `{"ok":true,"status":"scheduled"}` immediately and the swap
//! becomes visible in `metrics` (new default checksum + `rel_err`).
//!
//! Replies: `{"ok":true,"signal":[..]}` for transforms/filters/wavelets,
//! `{"ok":true,"indices":[..],"values":[..]}` for top-k (parallel arrays,
//! indices ascending), `{"ok":true,"metrics":{..}}`,
//! `{"ok":true,"checksum":"<16-hex>","n":N,"stages":G}` for uploads — or
//! `{"ok":false,"code":C,"error":MSG}` where `code` is one of
//! `queue_full` (plus `"retry_after_ms":N` — back off at least that
//! long), `deadline_exceeded`, `shutting_down`, `plan_unavailable`,
//! `unsupported_plan` (the route resolved but can't serve the request:
//! spectrum-less v1 artifact asked for a kernel filter, or the plan's
//! error certificate violates the server's `--max-error` budget),
//! `backend_error`, or `bad_request`.
//!
//! The `metrics` reply's `registry` object carries a `plans` array — one
//! entry per resident plan with its checksum, dimensions, and, when the
//! artifact is a certified v3 `.fastplan`, the measured `rel_err` /
//! `fro_err` of its error certificate (null otherwise).
//!
//! Signals travel as JSON numbers printed with Rust's shortest-round-trip
//! `f32` formatting and are re-parsed **directly as `f32`** (never through
//! `f64`), so a transform response is bitwise-identical to the in-process
//! answer.
//!
//! # Robustness
//!
//! * Malformed JSON in a well-framed request gets a `bad_request` reply
//!   and the connection stays usable (framing stays in sync).
//! * An oversized or short-read frame, a mid-frame client stall longer
//!   than `stall_timeout`, or a write failure (client vanished mid-reply)
//!   closes only that connection.
//! * Graceful drain: on shutdown (flag or SIGTERM via
//!   [`install_termination_handler`]) the listener stops accepting,
//!   connection threads finish the request they are on, answer
//!   `shutting_down` to anything further, and the coordinator drains its
//!   queue before the final metrics are returned.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use super::{
    Coordinator, FilterSpec, JobOp, MetricsSnapshot, Payload, Priority, RefactorJob,
    RefactorOptions, RefactorWorker, ResponseSpec, ServeError, SubmitOptions, TopKSpec,
    WaveletSpec,
};
use crate::linalg::Mat;
use crate::ops::{SpectralKernel, TopK};
use crate::plan::Plan;

/// Hard cap on request/reply payload size (64 MiB — a full batch of
/// million-point signals fits with room to spare).
pub const MAX_FRAME: usize = 64 << 20;

/// Front-end tunables.
#[derive(Clone, Debug)]
pub struct NetServerOptions {
    /// Socket read-timeout granularity: how often an idle connection
    /// re-checks the drain flag.
    pub read_poll: Duration,
    /// Budget for a client stalled *mid-frame* before its connection is
    /// closed (a stall between frames is just an idle connection).
    pub stall_timeout: Duration,
    /// Socket write timeout (slow-reading clients are disconnected).
    pub write_timeout: Duration,
    /// How long a connection waits for the coordinator's reply before
    /// answering `backend_error` (bounds connection-thread blocking even
    /// if the worker wedges).
    pub reply_timeout: Duration,
    /// Per-frame payload cap.
    pub max_frame: usize,
    /// Background refactor worker for `refactor` wire requests /
    /// `--watch-graph`. `None` answers `refactor` with `bad_request`.
    pub refactor: Option<Arc<RefactorWorker>>,
}

impl Default for NetServerOptions {
    fn default() -> Self {
        NetServerOptions {
            read_poll: Duration::from_millis(50),
            stall_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            reply_timeout: Duration::from_secs(60),
            max_frame: MAX_FRAME,
            refactor: None,
        }
    }
}

// ---------------------------------------------------------------------------
// minimal JSON (the crate snapshot has no serde)
// ---------------------------------------------------------------------------

/// A JSON value. Numbers keep their **raw text**: `f32` payloads are
/// re-parsed from it directly, never widened through `f64`, so signal
/// values round-trip bitwise.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// A number, as its raw wire text.
    Num(String),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Number from an `f32` using Rust's shortest-round-trip formatting
    /// (non-finite values have no JSON spelling and become `null`).
    pub fn f32(x: f32) -> Json {
        if x.is_finite() {
            Json::Num(format!("{x}"))
        } else {
            Json::Null
        }
    }

    /// Number from an `f64` (same non-finite rule).
    pub fn f64(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(format!("{x}"))
        } else {
            Json::Null
        }
    }

    /// Number from a `u64`.
    pub fn u64(x: u64) -> Json {
        Json::Num(x.to_string())
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a number **directly** as `f32` (bitwise round trip with
    /// [`Json::f32`]).
    pub fn as_f32(&self) -> Option<f32> {
        match self {
            Json::Num(raw) => raw.parse().ok(),
            _ => None,
        }
    }

    /// Parse a number as `f64`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(raw) => raw.parse().ok(),
            _ => None,
        }
    }

    /// Parse a number as `u64` (rejects fractions/negatives).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(raw) => raw.parse().ok(),
            _ => None,
        }
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Serialize to compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(raw) => out.push_str(raw),
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse JSON text (strict: one value, nothing but whitespace after).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos, 0)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing bytes after JSON value at offset {pos}");
        }
        Ok(v)
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

const MAX_DEPTH: usize = 64;

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> crate::Result<Json> {
    if depth > MAX_DEPTH {
        bail!("JSON nesting deeper than {MAX_DEPTH}");
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => bail!("unexpected end of JSON"),
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos, depth + 1)? {
                    Json::Str(s) => s,
                    _ => bail!("object key must be a string at offset {pos}"),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    bail!("expected ':' at offset {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos, depth + 1)?;
                fields.push((key, val));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => bail!("expected ',' or '}}' at offset {pos}"),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => bail!("expected ',' or ']' at offset {pos}"),
                }
            }
        }
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b't') => parse_lit(b, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => parse_lit(b, pos, "false").map(|_| Json::Bool(false)),
        Some(b'n') => parse_lit(b, pos, "null").map(|_| Json::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str) -> crate::Result<()> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        bail!("invalid JSON literal at offset {pos}")
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> crate::Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let digits_from = *pos;
    while matches!(b.get(*pos), Some(b'0'..=b'9')) {
        *pos += 1;
    }
    if *pos == digits_from {
        bail!("invalid JSON number at offset {start}");
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        let frac_from = *pos;
        while matches!(b.get(*pos), Some(b'0'..=b'9')) {
            *pos += 1;
        }
        if *pos == frac_from {
            bail!("invalid JSON number at offset {start}");
        }
    }
    if matches!(b.get(*pos), Some(b'e' | b'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some(b'+' | b'-')) {
            *pos += 1;
        }
        let exp_from = *pos;
        while matches!(b.get(*pos), Some(b'0'..=b'9')) {
            *pos += 1;
        }
        if *pos == exp_from {
            bail!("invalid JSON number at offset {start}");
        }
    }
    // the slice is ASCII by construction
    Ok(Json::Num(String::from_utf8_lossy(&b[start..*pos]).into_owned()))
}

fn parse_string(b: &[u8], pos: &mut usize) -> crate::Result<String> {
    *pos += 1; // opening quote
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated JSON string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = parse_hex4(b, *pos + 1)?;
                        *pos += 4;
                        let cp = if (0xd800..0xdc00).contains(&hi)
                            && b.get(*pos + 1) == Some(&b'\\')
                            && b.get(*pos + 2) == Some(&b'u')
                        {
                            let lo = parse_hex4(b, *pos + 3)?;
                            *pos += 6;
                            0x10000 + ((hi - 0xd800) << 10) + (lo.wrapping_sub(0xdc00) & 0x3ff)
                        } else {
                            hi
                        };
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => bail!("invalid JSON escape at offset {pos}"),
                }
                *pos += 1;
            }
            Some(&c) if c < 0x20 => bail!("raw control byte in JSON string at offset {pos}"),
            Some(_) => {
                // copy one UTF-8 scalar
                let s = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow!("invalid UTF-8 in JSON string at offset {pos}"))?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(b: &[u8], at: usize) -> crate::Result<u32> {
    let slice = b.get(at..at + 4).ok_or_else(|| anyhow!("truncated \\u escape"))?;
    let s = std::str::from_utf8(slice).map_err(|_| anyhow!("invalid \\u escape"))?;
    u32::from_str_radix(s, 16).map_err(|_| anyhow!("invalid \\u escape"))
}

// ---------------------------------------------------------------------------
// hex (plan checksums and upload payloads)
// ---------------------------------------------------------------------------

/// Lowercase hex encoding.
pub fn hex_encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Strict hex decoding (even length, hex digits only).
pub fn hex_decode(s: &str) -> crate::Result<Vec<u8>> {
    let s = s.as_bytes();
    if s.len() % 2 != 0 {
        bail!("hex string has odd length {}", s.len());
    }
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in s.chunks_exact(2) {
        let txt = std::str::from_utf8(pair).map_err(|_| anyhow!("non-ASCII hex"))?;
        out.push(u8::from_str_radix(txt, 16).with_context(|| format!("bad hex pair {txt:?}"))?);
    }
    Ok(out)
}

/// Parse a 16-hex-digit plan content checksum.
pub fn parse_checksum(s: &str) -> crate::Result<u64> {
    if s.len() != 16 {
        bail!("plan checksum must be 16 hex digits, got {:?}", s);
    }
    u64::from_str_radix(s, 16).with_context(|| format!("bad plan checksum {s:?}"))
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    let len = u32::try_from(payload.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame too large"))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Blocking read of one frame (for clients: no poll/drain machinery).
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut header = [0u8; 4];
    r.read_exact(&mut header)?;
    let len = u32::from_le_bytes(header) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "frame exceeds MAX_FRAME"));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// One round trip on a client connection: send `request`, read the reply.
pub fn request(stream: &mut TcpStream, request: &Json) -> crate::Result<Json> {
    write_frame(stream, request.render().as_bytes()).context("sending request frame")?;
    let payload = read_frame(stream).context("reading reply frame")?;
    let text = std::str::from_utf8(&payload).context("reply frame is not UTF-8")?;
    Json::parse(text)
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Server-side frame read over a socket whose read timeout is
/// `opts.read_poll`. Distinguishes an *idle* connection (no frame started
/// — waits forever, re-checking `draining` each poll) from a *mid-frame
/// stall* (budgeted by `opts.stall_timeout`). `Ok(None)` means the
/// connection should close quietly (EOF or drain).
fn read_frame_polled(
    stream: &mut TcpStream,
    opts: &NetServerOptions,
    draining: &AtomicBool,
) -> io::Result<Option<Vec<u8>>> {
    let mut header = [0u8; 4];
    let mut got = 0usize;
    // frame boundary: idle is fine, but leave when draining
    while got < header.len() {
        match stream.read(&mut header[got..]) {
            Ok(0) => return Ok(None), // EOF
            Ok(k) => {
                got += k;
                break;
            }
            Err(e) if is_timeout(&e) => {
                if draining.load(Ordering::SeqCst) {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    // a frame has started: the client gets stall_timeout to finish it
    let stall_budget = opts.stall_timeout;
    let mut stalled_since = Instant::now();
    while got < header.len() {
        match stream.read(&mut header[got..]) {
            Ok(0) => return Ok(None),
            Ok(k) => {
                got += k;
                stalled_since = Instant::now();
            }
            Err(e) if is_timeout(&e) => {
                if stalled_since.elapsed() > stall_budget {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "client stalled mid-frame",
                    ));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(header) as usize;
    if len > opts.max_frame {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {} byte cap", opts.max_frame),
        ));
    }
    let mut payload = vec![0u8; len];
    let mut have = 0usize;
    let mut stalled_since = Instant::now();
    while have < len {
        match stream.read(&mut payload[have..]) {
            Ok(0) => return Ok(None), // truncated frame: peer went away
            Ok(k) => {
                have += k;
                stalled_since = Instant::now();
            }
            Err(e) if is_timeout(&e) => {
                if stalled_since.elapsed() > stall_budget {
                    return Err(io::Error::new(
                        io::ErrorKind::TimedOut,
                        "client stalled mid-frame",
                    ));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// request handling
// ---------------------------------------------------------------------------

fn err_reply(code: &str, msg: &str, retry_after_ms: Option<u64>) -> Json {
    let mut fields = vec![
        ("ok".to_string(), Json::Bool(false)),
        ("code".to_string(), Json::Str(code.to_string())),
        ("error".to_string(), Json::Str(msg.to_string())),
    ];
    if let Some(ms) = retry_after_ms {
        fields.push(("retry_after_ms".to_string(), Json::u64(ms)));
    }
    Json::Obj(fields)
}

fn serve_error_reply(e: &ServeError) -> Json {
    let retry = match e {
        ServeError::Rejected(r) => r.retry_after_ms(),
        _ => None,
    };
    err_reply(e.code(), &e.to_string(), retry)
}

fn metrics_json(m: &MetricsSnapshot, coord: &Coordinator) -> Json {
    let mut fields = vec![
        ("completed".to_string(), Json::u64(m.completed)),
        ("errors".to_string(), Json::u64(m.errors)),
        ("rejected".to_string(), Json::u64(m.rejected)),
        ("rejected_queue_full".to_string(), Json::u64(m.rejected_queue_full)),
        ("rejected_deadline".to_string(), Json::u64(m.rejected_deadline)),
        ("rejected_shutdown".to_string(), Json::u64(m.rejected_shutdown)),
        ("rejected_plan_unavailable".to_string(), Json::u64(m.rejected_plan_unavailable)),
        ("rejected_unsupported_plan".to_string(), Json::u64(m.rejected_unsupported_plan)),
        ("panics_contained".to_string(), Json::u64(m.panics_contained)),
        ("p50_latency_s".to_string(), Json::f64(m.p50_latency_s)),
        ("p99_latency_s".to_string(), Json::f64(m.p99_latency_s)),
        ("mean_exec_s".to_string(), Json::f64(m.mean_exec_s)),
        ("mean_batch".to_string(), Json::f64(m.mean_batch)),
        ("max_batch_seen".to_string(), Json::u64(m.max_batch_seen as u64)),
        ("kernel_isa".to_string(), Json::Str(m.kernel_isa.to_string())),
        ("tuned".to_string(), Json::Str(m.tuned.clone())),
    ];
    if let Some(reg) = coord.registry() {
        let s = reg.stats();
        fields.push((
            "registry".to_string(),
            Json::Obj(vec![
                ("resident".to_string(), Json::u64(s.resident as u64)),
                ("capacity".to_string(), Json::u64(s.capacity as u64)),
                ("hits".to_string(), Json::u64(s.hits)),
                ("misses".to_string(), Json::u64(s.misses)),
                ("loads".to_string(), Json::u64(s.loads)),
                ("load_errors".to_string(), Json::u64(s.load_errors)),
                ("evictions".to_string(), Json::u64(s.evictions)),
                (
                    "default_checksum".to_string(),
                    s.default_checksum
                        .map_or(Json::Null, |k| Json::Str(format!("{k:016x}"))),
                ),
                (
                    "plans".to_string(),
                    Json::Arr(
                        reg.resident_plans()
                            .into_iter()
                            .map(|p| {
                                let (rel, fro, cg) = match &p.certificate {
                                    Some(c) => {
                                        (Json::f64(c.rel_err), Json::f64(c.fro_err), Json::u64(c.g as u64))
                                    }
                                    None => (Json::Null, Json::Null, Json::Null),
                                };
                                Json::Obj(vec![
                                    ("checksum".to_string(), Json::Str(format!("{:016x}", p.checksum))),
                                    ("n".to_string(), Json::u64(p.n as u64)),
                                    ("stages".to_string(), Json::u64(p.g as u64)),
                                    ("default".to_string(), Json::Bool(p.is_default)),
                                    ("rel_err".to_string(), rel),
                                    ("fro_err".to_string(), fro),
                                    ("cert_g".to_string(), cg),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ));
    }
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("metrics".to_string(), Json::Obj(fields)),
    ])
}

fn handle_transform(coord: &Coordinator, req: &Json, op: JobOp, opts: &NetServerOptions) -> Json {
    let Some(items) = req.get("signal").and_then(|v| v.as_arr()) else {
        return err_reply("bad_request", "transform request needs a \"signal\" array", None);
    };
    let mut signal = Vec::with_capacity(items.len());
    for v in items {
        match v.as_f32() {
            Some(x) => signal.push(x),
            None => {
                return err_reply("bad_request", "\"signal\" must hold finite numbers", None)
            }
        }
    }
    let mut submit = SubmitOptions { op, ..Default::default() };
    if let Some(p) = req.get("priority") {
        match p.as_str() {
            Some("interactive") => submit.priority = Priority::Interactive,
            Some("batch") => submit.priority = Priority::Batch,
            _ => return err_reply("bad_request", "\"priority\" must be interactive|batch", None),
        }
    }
    if let Some(d) = req.get("deadline_ms") {
        match d.as_u64() {
            Some(ms) => submit.deadline = Some(Instant::now() + Duration::from_millis(ms)),
            None => {
                return err_reply("bad_request", "\"deadline_ms\" must be a non-negative int", None)
            }
        }
    }
    if let Some(p) = req.get("plan") {
        match p.as_str().map(parse_checksum) {
            Some(Ok(key)) => submit.plan = Some(key),
            _ => return err_reply("bad_request", "\"plan\" must be a 16-hex checksum", None),
        }
    }
    let ticket = match coord.submit_with(signal, submit) {
        Ok(t) => t,
        Err(e) => return serve_error_reply(&e),
    };
    match ticket.wait_timeout(opts.reply_timeout) {
        Some(Ok(Payload::Dense(out))) => Json::Obj(vec![
            ("ok".to_string(), Json::Bool(true)),
            ("signal".to_string(), Json::Arr(out.into_iter().map(Json::f32).collect())),
        ]),
        Some(Ok(Payload::Sparse(sp))) => Json::Obj(vec![
            ("ok".to_string(), Json::Bool(true)),
            (
                "indices".to_string(),
                Json::Arr(sp.indices.into_iter().map(|i| Json::u64(i as u64)).collect()),
            ),
            (
                "values".to_string(),
                Json::Arr(sp.values.into_iter().map(Json::f32).collect()),
            ),
        ]),
        Some(Err(e)) => serve_error_reply(&e),
        None => err_reply(
            "backend_error",
            &format!("no reply within {:?}", opts.reply_timeout),
            None,
        ),
    }
}

/// Build the [`JobOp`] for a spectral request (`filter` / `wavelet` /
/// `topk`), or the `bad_request` reply describing what was malformed.
fn parse_spectral_op(op: &str, req: &Json) -> Result<JobOp, Json> {
    match op {
        "filter" => {
            match (req.get("response"), req.get("kernel")) {
                (Some(resp), None) => {
                    let Some(items) = resp.as_arr() else {
                        return Err(err_reply(
                            "bad_request",
                            "\"response\" must be an array of numbers",
                            None,
                        ));
                    };
                    let mut h = Vec::with_capacity(items.len());
                    for v in items {
                        match v.as_f64() {
                            Some(x) if x.is_finite() => h.push(x),
                            _ => {
                                return Err(err_reply(
                                    "bad_request",
                                    "\"response\" must hold finite numbers",
                                    None,
                                ))
                            }
                        }
                    }
                    Ok(JobOp::Filter(Arc::new(FilterSpec {
                        response: ResponseSpec::Explicit(h),
                    })))
                }
                (None, Some(kernel)) => {
                    let Some(name) = kernel.as_str() else {
                        return Err(err_reply("bad_request", "\"kernel\" must be a string", None));
                    };
                    let Some(param) = req.get("param").and_then(|v| v.as_f64()) else {
                        return Err(err_reply(
                            "bad_request",
                            "kernel filters need a numeric \"param\"",
                            None,
                        ));
                    };
                    match SpectralKernel::from_name(name, param) {
                        Ok(k) => Ok(JobOp::Filter(Arc::new(FilterSpec {
                            response: ResponseSpec::Kernel(k),
                        }))),
                        Err(e) => Err(err_reply("bad_request", &format!("{e:#}"), None)),
                    }
                }
                _ => Err(err_reply(
                    "bad_request",
                    "filter requests need exactly one of \"response\" or \"kernel\"+\"param\"",
                    None,
                )),
            }
        }
        "wavelet" => match req.get("scales").and_then(|v| v.as_u64()) {
            Some(j) if j >= 1 => {
                Ok(JobOp::Wavelet(Arc::new(WaveletSpec { scales: j as usize })))
            }
            _ => Err(err_reply(
                "bad_request",
                "wavelet requests need an integer \"scales\" >= 1",
                None,
            )),
        },
        "topk" => {
            let k = match req.get("k") {
                Some(v) => match v.as_u64() {
                    Some(k) => k as usize,
                    None => {
                        return Err(err_reply(
                            "bad_request",
                            "\"k\" must be a non-negative integer",
                            None,
                        ))
                    }
                },
                None => 0,
            };
            let threshold = match req.get("threshold") {
                Some(v) => match v.as_f32() {
                    Some(t) => t,
                    None => {
                        return Err(err_reply("bad_request", "\"threshold\" must be a number", None))
                    }
                },
                None => 0.0,
            };
            let rule = TopK { k, threshold };
            if let Err(e) = rule.validate() {
                return Err(err_reply("bad_request", &format!("{e:#}"), None));
            }
            Ok(JobOp::TopK(Arc::new(TopKSpec { rule })))
        }
        other => Err(err_reply("bad_request", &format!("not a spectral op: {other:?}"), None)),
    }
}

fn handle_upload(coord: &Coordinator, req: &Json) -> Json {
    let Some(reg) = coord.registry() else {
        return err_reply("bad_request", "this server has no plan registry", None);
    };
    let Some(hex) = req.get("bytes").and_then(|v| v.as_str()) else {
        return err_reply("bad_request", "upload_plan needs hex \"bytes\"", None);
    };
    let bytes = match hex_decode(hex) {
        Ok(b) => b,
        Err(e) => return err_reply("bad_request", &format!("{e:#}"), None),
    };
    let plan: Arc<Plan> = match Plan::from_bytes(&bytes) {
        Ok(p) => p,
        Err(e) => return err_reply("bad_request", &format!("rejected plan bytes: {e:#}"), None),
    };
    let n = plan.n();
    let stages = plan.len();
    let make_default = req.get("default").and_then(|v| v.as_bool()).unwrap_or(false);
    let key = if make_default { reg.install_default(plan) } else { reg.insert(plan) };
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("checksum".to_string(), Json::Str(format!("{key:016x}"))),
        ("n".to_string(), Json::u64(n as u64)),
        ("stages".to_string(), Json::u64(stages as u64)),
        ("default".to_string(), Json::Bool(make_default)),
    ])
}

fn handle_refactor(coord: &Coordinator, req: &Json, opts: &NetServerOptions) -> Json {
    if coord.registry().is_none() {
        return err_reply("bad_request", "this server has no plan registry", None);
    }
    let Some(worker) = opts.refactor.as_ref() else {
        return err_reply("bad_request", "this server has no refactor worker", None);
    };
    let Some(items) = req.get("matrix").and_then(|v| v.as_arr()) else {
        return err_reply("bad_request", "refactor needs a row-major \"matrix\" array", None);
    };
    let mut data = Vec::with_capacity(items.len());
    for v in items {
        match v.as_f64() {
            Some(x) if x.is_finite() => data.push(x),
            _ => return err_reply("bad_request", "\"matrix\" must hold finite numbers", None),
        }
    }
    let n = (data.len() as f64).sqrt().round() as usize;
    if n == 0 || n * n != data.len() {
        return err_reply(
            "bad_request",
            &format!("\"matrix\" has {} entries, not a square n×n count", data.len()),
            None,
        );
    }
    let matrix = Mat::from_rows(n, n, &data);
    let from = match req.get("from") {
        Some(v) => match v.as_str().map(parse_checksum) {
            Some(Ok(key)) => Some(key),
            _ => return err_reply("bad_request", "\"from\" must be a 16-hex checksum", None),
        },
        None => None,
    };
    let mut ropts = RefactorOptions { max_error: coord.max_error(), ..Default::default() };
    if let Some(v) = req.get("budget") {
        match v.as_f64() {
            Some(b) if b.is_finite() && b > 0.0 => ropts.budget = Some(b),
            _ => return err_reply("bad_request", "\"budget\" must be a positive number", None),
        }
    }
    if let Some(v) = req.get("max_g") {
        match v.as_u64() {
            Some(g) if g >= 1 => ropts.max_g = Some(g as usize),
            _ => return err_reply("bad_request", "\"max_g\" must be an integer >= 1", None),
        }
    }
    let sync = req.get("sync").and_then(|v| v.as_bool()).unwrap_or(false);
    if !sync {
        if !worker.submit(RefactorJob { matrix, from, opts: ropts, reply: None }) {
            return err_reply("backend_error", "refactor worker is gone", None);
        }
        return Json::Obj(vec![
            ("ok".to_string(), Json::Bool(true)),
            ("status".to_string(), Json::Str("scheduled".to_string())),
        ]);
    }
    let (tx, rx) = std::sync::mpsc::channel();
    if !worker.submit(RefactorJob { matrix, from, opts: ropts, reply: Some(tx) }) {
        return err_reply("backend_error", "refactor worker is gone", None);
    }
    match rx.recv_timeout(opts.reply_timeout) {
        Ok(Ok(o)) => {
            let mut fields = vec![
                ("ok".to_string(), Json::Bool(true)),
                ("swapped".to_string(), Json::Bool(o.swapped)),
                ("checksum".to_string(), Json::Str(format!("{:016x}", o.new_checksum))),
                ("old_checksum".to_string(), Json::Str(format!("{:016x}", o.old_checksum))),
                ("rel_err".to_string(), Json::f64(o.rel_err)),
                ("g".to_string(), Json::u64(o.g as u64)),
                ("sweeps".to_string(), Json::u64(o.sweeps as u64)),
                ("growth_rounds".to_string(), Json::u64(o.growth_rounds as u64)),
                ("factors_added".to_string(), Json::u64(o.factors_added as u64)),
            ];
            if let Some(msg) = o.refused {
                fields.push(("refused".to_string(), Json::Str(msg)));
            }
            Json::Obj(fields)
        }
        Ok(Err(e)) => err_reply("bad_request", &format!("refactor failed: {e:#}"), None),
        Err(_) => err_reply(
            "backend_error",
            &format!("refactor did not finish within {:?}", opts.reply_timeout),
            None,
        ),
    }
}

/// Answer one request frame (exposed for tests).
pub fn handle_request(
    coord: &Coordinator,
    payload: &[u8],
    opts: &NetServerOptions,
    draining: &AtomicBool,
) -> Json {
    let req = match std::str::from_utf8(payload).map_err(anyhow::Error::from).and_then(Json::parse)
    {
        Ok(v) => v,
        Err(e) => return err_reply("bad_request", &format!("malformed JSON frame: {e:#}"), None),
    };
    let op = req.get("op").and_then(|v| v.as_str()).unwrap_or("");
    match op {
        "metrics" => metrics_json(&coord.metrics(), coord),
        "upload_plan" => {
            if draining.load(Ordering::SeqCst) {
                return err_reply("shutting_down", "coordinator is shutting down", None);
            }
            handle_upload(coord, &req)
        }
        "refactor" => {
            if draining.load(Ordering::SeqCst) {
                return err_reply("shutting_down", "coordinator is shutting down", None);
            }
            handle_refactor(coord, &req, opts)
        }
        "submit" | "forward" | "adjoint" => {
            if draining.load(Ordering::SeqCst) {
                return err_reply("shutting_down", "coordinator is shutting down", None);
            }
            let job_op = if op == "adjoint" { JobOp::Adjoint } else { JobOp::Forward };
            handle_transform(coord, &req, job_op, opts)
        }
        "filter" | "wavelet" | "topk" => {
            if draining.load(Ordering::SeqCst) {
                return err_reply("shutting_down", "coordinator is shutting down", None);
            }
            match parse_spectral_op(op, &req) {
                Ok(job_op) => handle_transform(coord, &req, job_op, opts),
                Err(reply) => reply,
            }
        }
        other => err_reply(
            "bad_request",
            &format!(
                "unknown op {other:?} (want submit|forward|adjoint|filter|wavelet|topk|\
                 metrics|upload_plan|refactor)"
            ),
            None,
        ),
    }
}

fn handle_conn(
    mut stream: TcpStream,
    coord: &Coordinator,
    opts: &NetServerOptions,
    draining: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(opts.read_poll)).is_err()
        || stream.set_write_timeout(Some(opts.write_timeout)).is_err()
    {
        return;
    }
    loop {
        match read_frame_polled(&mut stream, opts, draining) {
            Ok(Some(payload)) => {
                let reply = handle_request(coord, &payload, opts, draining);
                if write_frame(&mut stream, reply.render().as_bytes()).is_err() {
                    // client vanished mid-reply: their loss, not ours
                    return;
                }
            }
            // EOF / drain: quiet close
            Ok(None) => return,
            // oversized frame, mid-frame stall, hard socket error: the
            // framing is no longer trustworthy, so close rather than
            // risk replying into the middle of a stream
            Err(_) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// termination + server loop
// ---------------------------------------------------------------------------

static TERM: AtomicBool = AtomicBool::new(false);

extern "C" fn on_term(_signum: i32) {
    // only async-signal-safe work here: one atomic store
    TERM.store(true, Ordering::SeqCst);
}

/// Route SIGTERM/SIGINT into the graceful-drain flag checked by
/// [`serve`]. (The libc `signal` symbol is already linked by std; the
/// crate snapshot has no libc crate to declare it for us.)
pub fn install_termination_handler() {
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
        signal(SIGINT, on_term as extern "C" fn(i32) as usize);
    }
}

/// Whether a termination signal has been delivered.
pub fn termination_requested() -> bool {
    TERM.load(Ordering::SeqCst)
}

/// Run the front-end until `shutdown` is set or a termination signal
/// arrives, then drain gracefully: stop accepting, let every connection
/// finish its in-flight request, shut the coordinator down (draining its
/// queue), and return the final metrics.
pub fn serve(
    listener: TcpListener,
    coordinator: Coordinator,
    opts: NetServerOptions,
    shutdown: Arc<AtomicBool>,
) -> crate::Result<MetricsSnapshot> {
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let coord = Arc::new(coordinator);
    let draining = Arc::new(AtomicBool::new(false));
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if shutdown.load(Ordering::SeqCst) || termination_requested() {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // accepted sockets must not inherit the listener's
                // non-blocking mode
                let _ = stream.set_nonblocking(false);
                let coord = Arc::clone(&coord);
                let opts = opts.clone();
                let draining = Arc::clone(&draining);
                let h = std::thread::Builder::new()
                    .name("fastes-conn".into())
                    .spawn(move || handle_conn(stream, &coord, &opts, &draining))
                    .context("spawning connection thread")?;
                conns.push(h);
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if is_timeout(&e) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(anyhow::Error::from(e).context("accepting connections")),
        }
    }
    // graceful drain: stop accepting, finish in-flight requests, then
    // drain the coordinator queue
    drop(listener);
    draining.store(true, Ordering::SeqCst);
    for h in conns {
        let _ = h.join();
    }
    // every connection thread is gone, so ours is the last Arc
    match Arc::try_unwrap(coord) {
        Ok(c) => Ok(c.shutdown()),
        Err(c) => Ok(c.metrics()), // unreachable, but never panic on drain
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trips_structures() {
        let text = r#"{"op":"forward","signal":[1,-0.5,3.25e2],"deep":{"a":[true,false,null],"s":"q\"\\\né"}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("forward"));
        let sig = v.get("signal").unwrap().as_arr().unwrap();
        assert_eq!(sig[0].as_f32(), Some(1.0));
        assert_eq!(sig[1].as_f32(), Some(-0.5));
        assert_eq!(sig[2].as_f32(), Some(325.0));
        assert_eq!(
            v.get("deep").unwrap().get("s").unwrap().as_str(),
            Some("q\"\\\né")
        );
        // render → parse is the identity on the value
        let again = Json::parse(&v.render()).unwrap();
        assert_eq!(again, v);
    }

    #[test]
    fn json_rejects_malformed_input() {
        for bad in [
            "", "{", "[1,]", "{\"a\":}", "{'a':1}", "{\"a\":1}x", "nul", "+5", "1.", "--3",
            "\"unterminated", "{\"a\" 1}",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed JSON {bad:?}");
        }
        // depth bomb
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err(), "accepted 100-deep nesting");
    }

    #[test]
    fn f32_payloads_round_trip_bitwise() {
        // shortest-repr f32 text re-parsed as f32 must be bit-identical,
        // including values that differ if widened through f64 first
        let mut rng = crate::linalg::Rng64::new(99);
        for _ in 0..2000 {
            let x = (rng.randn() * 1e3) as f32;
            let j = Json::f32(x);
            assert_eq!(j.as_f32().unwrap().to_bits(), x.to_bits(), "{j:?}");
        }
        for x in [0.0f32, -0.0, f32::MIN_POSITIVE, f32::MAX, 1e-40 /* subnormal */] {
            assert_eq!(Json::f32(x).as_f32().unwrap().to_bits(), x.to_bits());
        }
        assert_eq!(Json::f32(f32::NAN), Json::Null, "non-finite becomes null");
    }

    #[test]
    fn hex_and_checksum_helpers() {
        assert_eq!(hex_encode(&[0x00, 0xff, 0x1a]), "00ff1a");
        assert_eq!(hex_decode("00ff1a").unwrap(), vec![0x00, 0xff, 0x1a]);
        assert!(hex_decode("abc").is_err(), "odd length");
        assert!(hex_decode("zz").is_err(), "non-hex");
        assert_eq!(parse_checksum("00000000000000ff").unwrap(), 0xff);
        assert!(parse_checksum("ff").is_err(), "checksums are exactly 16 digits");
    }

    #[test]
    fn frames_round_trip_over_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, br#"{"op":"metrics"}"#).unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), br#"{"op":"metrics"}"#.to_vec());
        assert_eq!(read_frame(&mut r).unwrap(), b"".to_vec());
        // a length prefix beyond the cap is rejected before allocation
        let mut huge = Vec::new();
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut &huge[..]).is_err());
    }
}
