//! Serving backends: native rust butterflies or a PJRT artifact.

use std::sync::Arc;

use anyhow::bail;

use super::{JobOp, Payload};
use crate::ops::{FilterOp, WaveletBank};
use crate::plan::{Direction, ExecPolicy, FastOperator, Plan};
use crate::runtime::autotune::{self, TuneProfile, TunedConfig};
use crate::runtime::{ArtifactKind, ArtifactStore};
use crate::transforms::{batch::SignalBlock, ChainKind, PlanArrays};

/// Which direction of the transform the backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformDirection {
    /// Analysis / forward GFT: `x̂ = Ūᵀ x`.
    Forward,
    /// Synthesis / inverse GFT: `x = Ū x̂`.
    Inverse,
    /// Spectral filtering: `y = Ū diag(h) Ūᵀ x`.
    Filter,
}

/// A batch-transform execution engine. Lives entirely on the worker
/// thread (constructed there by the [`super::Coordinator::start`]
/// factory), so it need not be `Send`.
pub trait Backend {
    /// Signal dimension.
    fn n(&self) -> usize;
    /// Maximum (= compiled) batch size.
    fn max_batch(&self) -> usize;
    /// Transform the block in place (columns beyond the live batch are
    /// padding and may hold anything).
    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()>;
    /// Apply the adjoint of [`Backend::forward`] in place (the synthesis
    /// direction when `forward` is the analysis GFT). Backends that only
    /// compile one direction keep the default, which answers with a typed
    /// error instead of wrong numbers.
    fn adjoint(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        let _ = block;
        bail!("backend {} does not serve the adjoint direction", self.name())
    }
    /// Execute a registry-routed plan (resolved per request by the
    /// coordinator) instead of the backend's own fixed route. The default
    /// rejects routing — only backends that can execute an arbitrary
    /// [`Plan`] (the native one) override it.
    ///
    /// Returns `None` when the answer is the transformed block itself
    /// (dense, in place); `Some(payloads)` — one entry per block column —
    /// when the op produces its own payloads (wavelet stacks, sparse
    /// top-k coefficients).
    fn apply_routed(
        &mut self,
        plan: &Arc<Plan>,
        op: &JobOp,
        block: &mut SignalBlock,
    ) -> crate::Result<Option<Vec<Payload>>> {
        let _ = (plan, op, block);
        bail!("backend {} cannot serve registry-routed plans", self.name())
    }
    /// Diagnostic name.
    fn name(&self) -> &str;
    /// SIMD kernel ISA the backend's applies dispatch to (`"n/a"` for
    /// backends that do not run the native kernels). Recorded in serve
    /// metrics so deployments can see which kernel actually serves.
    fn kernel_isa(&self) -> &'static str {
        "n/a"
    }
    /// Auto-tuning report: `(summary, sweeps)` when the backend's policy
    /// came from the execution autotuner — `summary` is the stable label
    /// of the chosen config and `sweeps` the number of candidates this
    /// startup actually measured (0 when the answer came from a cache or
    /// a preloaded `.fasttune` profile). `None` for untuned backends.
    /// Recorded in serve metrics as `tuned=` / `sweeps=`.
    fn tuned(&self) -> Option<(String, u64)> {
        None
    }
}

/// Native rust butterfly fast path (the Fig.-6 "C implementation"
/// analogue): one shared [`Plan`] applied through the
/// [`FastOperator`] trait, with the engine chosen by an [`ExecPolicy`] —
/// sequential, spawn-per-apply, or (the serving default) the process-wide
/// persistent worker pool with fused cache-blocked apply. Every engine is
/// bitwise identical to the sequential one.
pub struct NativeGftBackend {
    plan: Arc<Plan>,
    policy: ExecPolicy,
    direction: TransformDirection,
    max_batch: usize,
    /// Fused spectral filter (Filter direction only): the configured
    /// diagonal compiled into a [`FilterOp`], so the fixed filter route
    /// runs the one-traversal fused path like routed filter requests.
    filter_op: Option<FilterOp>,
    /// `(summary, sweeps)` when the policy came from the autotuner.
    tuned: Option<(String, u64)>,
}

impl NativeGftBackend {
    /// New backend over a shared plan with an explicit execution policy —
    /// the one constructor behind `fastes serve --exec seq|spawn|pool|auto`.
    /// [`ExecPolicy::Auto`] is resolved here, once, through the
    /// execution autotuner (`FASTES_AUTOTUNE` effort, cached process-wide),
    /// so the request path always runs a concrete engine.
    /// Fails when the plan is not a G-chain plan or the filter diagonal
    /// is missing/mis-sized for [`TransformDirection::Filter`].
    pub fn with_policy(
        plan: Arc<Plan>,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        policy: ExecPolicy,
    ) -> crate::Result<Self> {
        if plan.kind() != ChainKind::G {
            bail!("the GFT backend serves G-chain plans (got a T-chain plan)");
        }
        let filter_op = match direction {
            TransformDirection::Filter => {
                let Some(h) = filter.as_ref().filter(|h| h.len() == plan.n()) else {
                    bail!("filter direction needs a length-{} diagonal", plan.n());
                };
                let h64: Vec<f64> = h.iter().map(|&v| v as f64).collect();
                Some(FilterOp::new(Arc::clone(&plan), h64)?)
            }
            _ => None,
        };
        let (policy, tuned) = match policy {
            ExecPolicy::Auto => {
                let resolved = autotune::resolve(&plan, max_batch);
                let summary = resolved.tuned.summary();
                (resolved.tuned.policy.clone(), Some((summary, resolved.swept as u64)))
            }
            concrete => (concrete, None),
        };
        Ok(NativeGftBackend { plan, policy, direction, max_batch, filter_op, tuned })
    }

    /// Backend over a sweep result (`fastes serve --autotune`): runs the
    /// tuned policy and reports `(summary, swept)` in serve metrics.
    pub fn with_tuned(
        plan: Arc<Plan>,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        tuned: &TunedConfig,
        swept: u64,
    ) -> crate::Result<Self> {
        let mut backend =
            Self::with_policy(plan, direction, max_batch, filter, tuned.policy.clone())?;
        backend.tuned = Some((tuned.summary(), swept));
        Ok(backend)
    }

    /// Backend over a preloaded `.fasttune` profile (`fastes serve
    /// --tune-profile`): validates that the profile was calibrated for
    /// exactly this plan and batch bucket, then serves under its policy
    /// with **zero** startup sweeps (metrics report `sweeps=0`).
    pub fn with_tune_profile(
        plan: Arc<Plan>,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        profile: &TuneProfile,
    ) -> crate::Result<Self> {
        profile.ensure_matches(&plan, max_batch)?;
        Self::with_tuned(plan, direction, max_batch, filter, &profile.tuned_config(), 0)
    }

    /// The shared plan this backend serves.
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// The execution policy applies run under.
    pub fn policy(&self) -> &ExecPolicy {
        &self.policy
    }
}

/// The backend *is* a [`FastOperator`]: it exposes the underlying
/// operator direction-polymorphically (the serve-time
/// [`TransformDirection`] mapping — Forward ⇒ adjoint, Inverse ⇒ forward,
/// Filter ⇒ adjoint·diag(h)·forward — lives only in
/// [`Backend::forward`]).
impl FastOperator for NativeGftBackend {
    fn n(&self) -> usize {
        self.plan.n()
    }

    fn flops(&self) -> usize {
        FastOperator::flops(self.plan.as_ref())
    }

    fn apply(
        &self,
        block: &mut SignalBlock,
        dir: Direction,
        policy: &ExecPolicy,
    ) -> crate::Result<()> {
        self.plan.apply(block, dir, policy)
    }

    fn apply_vec(&self, x: &mut [f64], dir: Direction) -> crate::Result<()> {
        self.plan.apply_vec(x, dir)
    }

    fn apply_mat(&self, m: &mut crate::linalg::Mat, dir: Direction) -> crate::Result<()> {
        self.plan.apply_mat(m, dir)
    }
}

impl Backend for NativeGftBackend {
    fn n(&self) -> usize {
        self.plan.n()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        match self.direction {
            // analysis / forward GFT: x̂ = Ūᵀ x
            TransformDirection::Forward => {
                self.plan.apply(block, Direction::Adjoint, &self.policy)
            }
            // synthesis / inverse GFT: x = Ū x̂
            TransformDirection::Inverse => {
                self.plan.apply(block, Direction::Forward, &self.policy)
            }
            // spectral filter: y = Ū diag(h) Ūᵀ x, one fused traversal
            TransformDirection::Filter => {
                let f = self.filter_op.as_ref().expect("checked in with_policy");
                f.apply(block, Direction::Forward, &self.policy)
            }
        }
    }

    fn adjoint(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        match self.direction {
            // forward() is the analysis GFT, so the adjoint is synthesis
            TransformDirection::Forward => {
                self.plan.apply(block, Direction::Forward, &self.policy)
            }
            TransformDirection::Inverse => {
                self.plan.apply(block, Direction::Adjoint, &self.policy)
            }
            // Ū diag(h) Ūᵀ is symmetric: the filter is its own adjoint
            TransformDirection::Filter => self.forward(block),
        }
    }

    fn apply_routed(
        &mut self,
        plan: &Arc<Plan>,
        op: &JobOp,
        block: &mut SignalBlock,
    ) -> crate::Result<Option<Vec<Payload>>> {
        if plan.kind() != ChainKind::G {
            bail!("the GFT backend serves G-chain plans (got a T-chain plan)");
        }
        if plan.n() != block.n {
            bail!("routed plan n {} != block n {}", plan.n(), block.n);
        }
        match op {
            // analysis x̂ = Ūᵀ x
            JobOp::Forward => {
                plan.apply(block, Direction::Adjoint, &self.policy)?;
                Ok(None)
            }
            // synthesis x = Ū x̂
            JobOp::Adjoint => {
                plan.apply(block, Direction::Forward, &self.policy)?;
                Ok(None)
            }
            // fused spectral filter on the routed plan; kernel specs
            // resolve against *this* plan's spectrum, so in-flight
            // requests drain on the plan they resolved at submit even
            // across a registry hot swap
            JobOp::Filter(spec) => {
                let f = FilterOp::new(Arc::clone(plan), spec.resolve(plan)?)?;
                f.apply(block, Direction::Forward, &self.policy)?;
                Ok(None)
            }
            // shared-prefix wavelet bank: the reply for column b is the
            // band-major stack [band0 | band1 | …] of length (J+1)·n
            JobOp::Wavelet(spec) => {
                let bank = WaveletBank::hammond(Arc::clone(plan), spec.scales)?;
                let bands = bank.analyze(block, &self.policy)?;
                let payloads = (0..block.batch)
                    .map(|b| {
                        let mut stacked = Vec::with_capacity(bands.len() * block.n);
                        for band in &bands {
                            stacked.extend(band.signal(b));
                        }
                        Payload::Dense(stacked)
                    })
                    .collect();
                Ok(Some(payloads))
            }
            // top-k compression of the spectral coefficients
            JobOp::TopK(spec) => {
                let sparse = spec.rule.compress_spectral(plan, block, &self.policy)?;
                Ok(Some(sparse.into_iter().map(Payload::Sparse).collect()))
            }
        }
    }

    fn name(&self) -> &str {
        match self.policy {
            ExecPolicy::Seq => "native-gft",
            ExecPolicy::Spawn(_) => "native-gft-scheduled",
            ExecPolicy::Pool(_) => "native-gft-pooled",
            // with_policy resolves Auto at construction; this arm only
            // keeps the match exhaustive
            ExecPolicy::Auto => "native-gft-auto",
        }
    }

    fn kernel_isa(&self) -> &'static str {
        self.policy.kernel_isa().as_str()
    }

    fn tuned(&self) -> Option<(String, u64)> {
        self.tuned.clone()
    }
}

/// PJRT-artifact backend: executes the AOT-compiled JAX/Pallas program.
pub struct PjrtGftBackend {
    store: ArtifactStore,
    artifact: String,
    plan: PlanArrays,
    filter: Option<Vec<f32>>,
    n: usize,
    batch: usize,
}

impl PjrtGftBackend {
    /// Bind a plan to a compatible artifact from `store` (matching kind /
    /// n / batch, with plan capacity ≥ the plan length). Compiles eagerly
    /// so the request path never pays compilation.
    pub fn new(
        mut store: ArtifactStore,
        direction: TransformDirection,
        plan: PlanArrays,
        batch: usize,
        filter: Option<Vec<f32>>,
    ) -> crate::Result<Self> {
        let kind = match direction {
            TransformDirection::Forward => ArtifactKind::GftFwd,
            TransformDirection::Inverse => ArtifactKind::GftInv,
            TransformDirection::Filter => ArtifactKind::GraphFilter,
        };
        let meta = store
            .find_with_capacity(kind, plan.n, batch, plan.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={} n={} batch={batch} g≥{}",
                    kind.as_str(),
                    plan.n,
                    plan.len()
                )
            })?
            .clone();
        if kind == ArtifactKind::GraphFilter && filter.as_ref().map_or(true, |h| h.len() != plan.n)
        {
            bail!("graph_filter backend needs a length-n filter");
        }
        store.engine(&meta.name)?; // compile now
        Ok(PjrtGftBackend {
            store,
            artifact: meta.name,
            n: plan.n,
            batch,
            plan,
            filter,
        })
    }
}

impl Backend for PjrtGftBackend {
    fn n(&self) -> usize {
        self.n
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        let engine = self.store.engine(&self.artifact)?;
        let out = engine.execute(&self.plan, block, self.filter.as_deref())?;
        *block = out;
        Ok(())
    }

    fn name(&self) -> &str {
        "pjrt-gft"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;
    use crate::transforms::{ExecConfig, GChain, GKind, GTransform};

    fn random_plan(n: usize, g: usize, seed: u64) -> Arc<Plan> {
        let mut rng = Rng64::new(seed);
        let mut ch = GChain::identity(n);
        for _ in 0..g {
            let i = rng.below(n - 1);
            let j = i + 1 + rng.below(n - 1 - i);
            let th = rng.uniform_in(0.0, std::f64::consts::TAU);
            let kind = if rng.bernoulli(0.5) { GKind::Rotation } else { GKind::Reflection };
            ch.transforms.push(GTransform::new(i, j, th.cos(), th.sin(), kind));
        }
        Plan::from(ch).build()
    }

    fn seq_backend(
        plan: &Arc<Plan>,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
    ) -> NativeGftBackend {
        let plan = Arc::clone(plan);
        NativeGftBackend::with_policy(plan, direction, max_batch, filter, ExecPolicy::Seq).unwrap()
    }

    #[test]
    fn native_forward_then_inverse_is_identity() {
        let plan = random_plan(8, 20, 601);
        let mut fwd = seq_backend(&plan, TransformDirection::Forward, 4, None);
        let mut inv = seq_backend(&plan, TransformDirection::Inverse, 4, None);
        let mut rng = Rng64::new(602);
        let sig: Vec<f32> = (0..8).map(|_| rng.randn() as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 4]).unwrap();
        fwd.forward(&mut block).unwrap();
        inv.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn filter_all_ones_is_identity() {
        let plan = random_plan(6, 15, 603);
        let mut f = seq_backend(&plan, TransformDirection::Filter, 2, Some(vec![1.0; 6]));
        let sig: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 2]).unwrap();
        f.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn every_policy_serves_identical_answers() {
        // same plan, every engine, every direction: the served responses
        // must agree bitwise (scheduling/fusion only reorder commuting
        // stages; SIMD kernels are bitwise-identical per element)
        let mut rng = Rng64::new(606);
        let plan = random_plan(16, 400, 605);
        let signals: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.randn() as f32).collect()).collect();
        let h: Vec<f32> = (0..16).map(|i| 1.0 / (1.0 + i as f32)).collect();
        // tiny thresholds so the parallel paths really engage
        let cfg =
            ExecConfig { threads: 3, min_work: 1, layer_min_work: 1.0, tile_cols: 2, kernel: None };
        for direction in
            [TransformDirection::Forward, TransformDirection::Inverse, TransformDirection::Filter]
        {
            let filter = (direction == TransformDirection::Filter).then(|| h.clone());
            let mut seq = seq_backend(&plan, direction, 6, filter.clone());
            let mut a = SignalBlock::from_signals(&signals).unwrap();
            seq.forward(&mut a).unwrap();
            for (policy, name) in [
                (ExecPolicy::Spawn(cfg.clone().with_threads(4)), "native-gft-scheduled"),
                (ExecPolicy::Pool(cfg.clone()), "native-gft-pooled"),
            ] {
                let mut engine = NativeGftBackend::with_policy(
                    Arc::clone(&plan),
                    direction,
                    6,
                    filter.clone(),
                    policy,
                )
                .unwrap();
                assert_eq!(engine.name(), name);
                let mut b = SignalBlock::from_signals(&signals).unwrap();
                engine.forward(&mut b).unwrap();
                assert_eq!(a.data, b.data, "{name} direction {direction:?} diverged");
            }
        }
    }

    #[test]
    fn with_policy_validates_inputs() {
        // T-chain plans are rejected
        let t = crate::transforms::TChain::identity(4);
        let tp = Plan::from(t).build();
        assert!(NativeGftBackend::with_policy(
            tp,
            TransformDirection::Forward,
            2,
            None,
            ExecPolicy::Seq
        )
        .is_err());
        // filter validation errors instead of panicking
        let plan = random_plan(12, 40, 610);
        assert!(NativeGftBackend::with_policy(
            plan,
            TransformDirection::Filter,
            2,
            Some(vec![1.0; 3]),
            ExecPolicy::Seq
        )
        .is_err());
    }

    #[test]
    fn backend_reports_kernel_isa() {
        let plan = random_plan(8, 20, 611);
        let b = seq_backend(&plan, TransformDirection::Forward, 2, None);
        let isa = crate::transforms::simd::default_kernel().as_str();
        assert_eq!(b.kernel_isa(), isa, "backend must report the dispatched kernel");
    }

    #[test]
    fn auto_policy_resolves_to_a_concrete_engine_and_reports_tuned() {
        let plan = random_plan(12, 120, 612);
        let b = NativeGftBackend::with_policy(
            Arc::clone(&plan),
            TransformDirection::Forward,
            8,
            None,
            ExecPolicy::Auto,
        )
        .unwrap();
        assert!(
            !matches!(b.policy(), ExecPolicy::Auto),
            "Auto must resolve to a concrete engine at construction"
        );
        let (summary, _sweeps) = b.tuned().expect("auto backend reports tuned info");
        assert!(summary.starts_with(b.policy().engine()), "{summary}");
    }

    #[test]
    fn tune_profile_backend_requires_a_matching_profile() {
        use crate::runtime::autotune::{resolve_with, TuneEffort, TuneProfile};
        let plan = random_plan(10, 80, 613);
        let r = resolve_with(&plan, 4, TuneEffort::Quick);
        let profile = TuneProfile::new(&plan, 4, &r.tuned);
        let b = NativeGftBackend::with_tune_profile(
            Arc::clone(&plan),
            TransformDirection::Forward,
            4,
            None,
            &profile,
        )
        .unwrap();
        assert_eq!(b.tuned(), Some((profile.summary(), 0)), "profile serves with zero sweeps");
        // a different plan and a different batch bucket are both rejected
        let other = random_plan(10, 80, 614);
        assert!(NativeGftBackend::with_tune_profile(
            other,
            TransformDirection::Forward,
            4,
            None,
            &profile
        )
        .is_err());
        assert!(NativeGftBackend::with_tune_profile(
            Arc::clone(&plan),
            TransformDirection::Forward,
            64,
            None,
            &profile
        )
        .is_err());
    }

    #[test]
    fn filter_zero_annihilates() {
        let plan = random_plan(5, 10, 604);
        let mut f = seq_backend(&plan, TransformDirection::Filter, 1, Some(vec![0.0; 5]));
        let mut block = SignalBlock::from_signals(&[vec![1.0, -2.0, 3.0, 0.5, 4.0]]).unwrap();
        f.forward(&mut block).unwrap();
        for v in block.signal(0) {
            assert!(v.abs() < 1e-6);
        }
    }
}
