//! Serving backends: native rust butterflies or a PJRT artifact.

use anyhow::bail;

use crate::runtime::{ArtifactKind, ArtifactStore};
use crate::transforms::{
    apply_gchain_batch_f32, apply_gchain_batch_f32_t, batch::SignalBlock, global_pool, ChainKind,
    CompiledPlan, ExecConfig, PlanArrays,
};

/// Which direction of the transform the backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformDirection {
    /// Analysis / forward GFT: `x̂ = Ūᵀ x`.
    Forward,
    /// Synthesis / inverse GFT: `x = Ū x̂`.
    Inverse,
    /// Spectral filtering: `y = Ū diag(h) Ūᵀ x`.
    Filter,
}

/// A batch-transform execution engine. Lives entirely on the worker
/// thread (constructed there by the [`super::Coordinator::start`]
/// factory), so it need not be `Send`.
pub trait Backend {
    /// Signal dimension.
    fn n(&self) -> usize;
    /// Maximum (= compiled) batch size.
    fn max_batch(&self) -> usize;
    /// Transform the block in place (columns beyond the live batch are
    /// padding and may hold anything).
    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()>;
    /// Diagnostic name.
    fn name(&self) -> &str;
}

/// Native rust butterfly fast path (the Fig.-6 "C implementation"
/// analogue). Optionally executes through a level-scheduled
/// [`CompiledPlan`] — either on the legacy spawn-per-apply executor or,
/// preferably, on the process-wide persistent worker pool with fused
/// cache-blocked apply (see [`crate::transforms::schedule`] and
/// [`crate::transforms::pool`]). Every compiled path is bitwise identical
/// to the sequential one.
pub struct NativeGftBackend {
    plan: PlanArrays,
    /// Level-scheduled execution plan (the parallel fast path).
    compiled: Option<CompiledPlan>,
    /// Worker threads for the compiled spawn path.
    threads: usize,
    /// When set, compiled applies run on [`global_pool`] with these
    /// tunables instead of spawning scoped threads.
    exec: Option<ExecConfig>,
    direction: TransformDirection,
    max_batch: usize,
    /// Spectral filter diagonal (Filter direction only).
    filter: Option<Vec<f32>>,
}

impl NativeGftBackend {
    /// New backend over a G-chain plan (sequential apply).
    pub fn new(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
    ) -> Self {
        Self::with_schedule(plan, direction, max_batch, filter, false, 1)
    }

    /// New backend with an explicit execution strategy: when `scheduled`,
    /// the plan is compiled into conflict-free layers at construction time
    /// and applied with up to `threads` spawned workers per batch.
    pub fn with_schedule(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        scheduled: bool,
        threads: usize,
    ) -> Self {
        if direction == TransformDirection::Filter {
            assert!(filter.as_ref().is_some_and(|h| h.len() == plan.n), "filter length mismatch");
        }
        let compiled = scheduled.then(|| CompiledPlan::from_plan(&plan, ChainKind::G));
        NativeGftBackend {
            plan,
            compiled,
            threads: threads.max(1),
            exec: None,
            direction,
            max_batch,
            filter,
        }
    }

    /// New backend on the persistent worker pool: the plan is compiled
    /// (levels + fused superstages) at construction time and every apply
    /// runs cache-blocked on the process-wide [`global_pool`] — no thread
    /// spawns on the request path. The serve coordinator's default.
    pub fn with_pool(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        cfg: ExecConfig,
    ) -> Self {
        let mut backend = Self::with_schedule(plan, direction, max_batch, filter, true, 1);
        backend.exec = Some(cfg);
        backend
    }

    /// `X ← diag(h) X` on the live block.
    fn scale_rows(block: &mut SignalBlock, h: &[f32]) {
        let b = block.batch;
        for (i, &hi) in h.iter().enumerate() {
            for v in &mut block.data[i * b..(i + 1) * b] {
                *v *= hi;
            }
        }
    }
}

impl Backend for NativeGftBackend {
    fn n(&self) -> usize {
        self.plan.n
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        if block.n != self.plan.n {
            bail!("block n {} != plan n {}", block.n, self.plan.n);
        }
        if let Some(cp) = &self.compiled {
            if let Some(cfg) = &self.exec {
                let pool = global_pool();
                match self.direction {
                    TransformDirection::Forward => cp.apply_batch_pooled_rev(block, pool, cfg),
                    TransformDirection::Inverse => cp.apply_batch_pooled(block, pool, cfg),
                    TransformDirection::Filter => {
                        let h = self.filter.as_ref().expect("checked in with_schedule");
                        cp.apply_batch_pooled_rev(block, pool, cfg);
                        Self::scale_rows(block, h);
                        cp.apply_batch_pooled(block, pool, cfg);
                    }
                }
                return Ok(());
            }
            match self.direction {
                TransformDirection::Forward => cp.apply_batch_rev(block, self.threads),
                TransformDirection::Inverse => cp.apply_batch(block, self.threads),
                TransformDirection::Filter => {
                    let h = self.filter.as_ref().expect("checked in with_schedule");
                    cp.apply_batch_rev(block, self.threads);
                    Self::scale_rows(block, h);
                    cp.apply_batch(block, self.threads);
                }
            }
            return Ok(());
        }
        match self.direction {
            TransformDirection::Forward => apply_gchain_batch_f32_t(&self.plan, block),
            TransformDirection::Inverse => apply_gchain_batch_f32(&self.plan, block),
            TransformDirection::Filter => {
                let h = self.filter.as_ref().expect("checked in with_schedule");
                apply_gchain_batch_f32_t(&self.plan, block);
                Self::scale_rows(block, h);
                apply_gchain_batch_f32(&self.plan, block);
            }
        }
        Ok(())
    }

    fn name(&self) -> &str {
        if self.exec.is_some() {
            "native-gft-pooled"
        } else if self.compiled.is_some() {
            "native-gft-scheduled"
        } else {
            "native-gft"
        }
    }
}

/// PJRT-artifact backend: executes the AOT-compiled JAX/Pallas program.
pub struct PjrtGftBackend {
    store: ArtifactStore,
    artifact: String,
    plan: PlanArrays,
    filter: Option<Vec<f32>>,
    n: usize,
    batch: usize,
}

impl PjrtGftBackend {
    /// Bind a plan to a compatible artifact from `store` (matching kind /
    /// n / batch, with plan capacity ≥ the plan length). Compiles eagerly
    /// so the request path never pays compilation.
    pub fn new(
        mut store: ArtifactStore,
        direction: TransformDirection,
        plan: PlanArrays,
        batch: usize,
        filter: Option<Vec<f32>>,
    ) -> crate::Result<Self> {
        let kind = match direction {
            TransformDirection::Forward => ArtifactKind::GftFwd,
            TransformDirection::Inverse => ArtifactKind::GftInv,
            TransformDirection::Filter => ArtifactKind::GraphFilter,
        };
        let meta = store
            .find_with_capacity(kind, plan.n, batch, plan.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={} n={} batch={batch} g≥{}",
                    kind.as_str(),
                    plan.n,
                    plan.len()
                )
            })?
            .clone();
        if kind == ArtifactKind::GraphFilter && filter.as_ref().map_or(true, |h| h.len() != plan.n)
        {
            bail!("graph_filter backend needs a length-n filter");
        }
        store.engine(&meta.name)?; // compile now
        Ok(PjrtGftBackend {
            store,
            artifact: meta.name,
            n: plan.n,
            batch,
            plan,
            filter,
        })
    }
}

impl Backend for PjrtGftBackend {
    fn n(&self) -> usize {
        self.n
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        let engine = self.store.engine(&self.artifact)?;
        let out = engine.execute(&self.plan, block, self.filter.as_deref())?;
        *block = out;
        Ok(())
    }

    fn name(&self) -> &str {
        "pjrt-gft"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;
    use crate::transforms::{GChain, GKind, GTransform};

    fn random_plan(n: usize, g: usize, seed: u64) -> PlanArrays {
        let mut rng = Rng64::new(seed);
        let mut ch = GChain::identity(n);
        for _ in 0..g {
            let i = rng.below(n - 1);
            let j = i + 1 + rng.below(n - 1 - i);
            let th = rng.uniform_in(0.0, std::f64::consts::TAU);
            let kind = if rng.bernoulli(0.5) { GKind::Rotation } else { GKind::Reflection };
            ch.transforms.push(GTransform::new(i, j, th.cos(), th.sin(), kind));
        }
        ch.to_plan()
    }

    #[test]
    fn native_forward_then_inverse_is_identity() {
        let plan = random_plan(8, 20, 601);
        let mut fwd = NativeGftBackend::new(plan.clone(), TransformDirection::Forward, 4, None);
        let mut inv = NativeGftBackend::new(plan, TransformDirection::Inverse, 4, None);
        let mut rng = Rng64::new(602);
        let sig: Vec<f32> = (0..8).map(|_| rng.randn() as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 4]);
        fwd.forward(&mut block).unwrap();
        inv.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn filter_all_ones_is_identity() {
        let plan = random_plan(6, 15, 603);
        let mut f = NativeGftBackend::new(
            plan,
            TransformDirection::Filter,
            2,
            Some(vec![1.0; 6]),
        );
        let sig: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 2]);
        f.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn scheduled_backend_matches_sequential() {
        let mut rng = Rng64::new(606);
        let plan = random_plan(16, 120, 605);
        let signals: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.randn() as f32).collect()).collect();
        let h: Vec<f32> = (0..16).map(|i| 1.0 / (1.0 + i as f32)).collect();
        for direction in
            [TransformDirection::Forward, TransformDirection::Inverse, TransformDirection::Filter]
        {
            let filter =
                (direction == TransformDirection::Filter).then(|| h.clone());
            let mut seq = NativeGftBackend::new(plan.clone(), direction, 6, filter.clone());
            let mut sched =
                NativeGftBackend::with_schedule(plan.clone(), direction, 6, filter, true, 4);
            assert_eq!(sched.name(), "native-gft-scheduled");
            let mut a = SignalBlock::from_signals(&signals);
            let mut b = SignalBlock::from_signals(&signals);
            seq.forward(&mut a).unwrap();
            sched.forward(&mut b).unwrap();
            assert_eq!(a.data, b.data, "direction {direction:?} diverged");
        }
    }

    #[test]
    fn pooled_backend_matches_sequential_bitwise() {
        // the pooled fast path must serve bit-identical answers to the
        // sequential backend in every direction (fusion only reorders
        // stages with disjoint supports)
        let mut rng = Rng64::new(608);
        let plan = random_plan(16, 400, 607);
        let signals: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.randn() as f32).collect()).collect();
        let h: Vec<f32> = (0..16).map(|i| 1.0 / (1.0 + i as f32)).collect();
        // tiny thresholds so the pooled parallel path really engages
        let cfg = ExecConfig { threads: 3, min_work: 1, layer_min_work: 1.0, tile_cols: 2 };
        for direction in
            [TransformDirection::Forward, TransformDirection::Inverse, TransformDirection::Filter]
        {
            let filter = (direction == TransformDirection::Filter).then(|| h.clone());
            let mut seq = NativeGftBackend::new(plan.clone(), direction, 6, filter.clone());
            let mut pooled =
                NativeGftBackend::with_pool(plan.clone(), direction, 6, filter, cfg.clone());
            assert_eq!(pooled.name(), "native-gft-pooled");
            let mut a = SignalBlock::from_signals(&signals);
            let mut b = SignalBlock::from_signals(&signals);
            seq.forward(&mut a).unwrap();
            pooled.forward(&mut b).unwrap();
            assert_eq!(a.data, b.data, "direction {direction:?} diverged");
        }
    }

    #[test]
    fn filter_zero_annihilates() {
        let plan = random_plan(5, 10, 604);
        let mut f = NativeGftBackend::new(
            plan,
            TransformDirection::Filter,
            1,
            Some(vec![0.0; 5]),
        );
        let mut block = SignalBlock::from_signals(&[vec![1.0, -2.0, 3.0, 0.5, 4.0]]);
        f.forward(&mut block).unwrap();
        for v in block.signal(0) {
            assert!(v.abs() < 1e-6);
        }
    }
}
