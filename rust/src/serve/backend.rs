//! Serving backends: native rust butterflies or a PJRT artifact.

use std::sync::Arc;

use anyhow::bail;

use crate::plan::{Direction, ExecPolicy, FastOperator, Plan};
use crate::runtime::{ArtifactKind, ArtifactStore};
use crate::transforms::{batch::SignalBlock, ChainKind, ExecConfig, GChain, PlanArrays};

/// Which direction of the transform the backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransformDirection {
    /// Analysis / forward GFT: `x̂ = Ūᵀ x`.
    Forward,
    /// Synthesis / inverse GFT: `x = Ū x̂`.
    Inverse,
    /// Spectral filtering: `y = Ū diag(h) Ūᵀ x`.
    Filter,
}

/// A batch-transform execution engine. Lives entirely on the worker
/// thread (constructed there by the [`super::Coordinator::start`]
/// factory), so it need not be `Send`.
pub trait Backend {
    /// Signal dimension.
    fn n(&self) -> usize;
    /// Maximum (= compiled) batch size.
    fn max_batch(&self) -> usize;
    /// Transform the block in place (columns beyond the live batch are
    /// padding and may hold anything).
    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()>;
    /// Diagnostic name.
    fn name(&self) -> &str;
}

/// Native rust butterfly fast path (the Fig.-6 "C implementation"
/// analogue): one shared [`Plan`] applied through the
/// [`FastOperator`] trait, with the engine chosen by an [`ExecPolicy`] —
/// sequential, spawn-per-apply, or (the serving default) the process-wide
/// persistent worker pool with fused cache-blocked apply. Every engine is
/// bitwise identical to the sequential one.
pub struct NativeGftBackend {
    plan: Arc<Plan>,
    policy: ExecPolicy,
    direction: TransformDirection,
    max_batch: usize,
    /// Spectral filter diagonal (Filter direction only).
    filter: Option<Vec<f32>>,
}

impl NativeGftBackend {
    /// New backend over a shared plan with an explicit execution policy —
    /// the one constructor behind `fastes serve --exec seq|spawn|pool`.
    /// Fails when the plan is not a G-chain plan or the filter diagonal
    /// is missing/mis-sized for [`TransformDirection::Filter`].
    pub fn with_policy(
        plan: Arc<Plan>,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        policy: ExecPolicy,
    ) -> crate::Result<Self> {
        if plan.kind() != ChainKind::G {
            bail!("the GFT backend serves G-chain plans (got a T-chain plan)");
        }
        if direction == TransformDirection::Filter
            && !filter.as_ref().is_some_and(|h| h.len() == plan.n())
        {
            bail!("filter direction needs a length-{} diagonal", plan.n());
        }
        Ok(NativeGftBackend { plan, policy, direction, max_batch, filter })
    }

    /// New backend over a G-chain plan (sequential apply).
    #[deprecated(note = "build an `Arc<Plan>` with `Plan::from(&chain).build()` and use \
                         `NativeGftBackend::with_policy` with `ExecPolicy::Seq`")]
    pub fn new(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
    ) -> Self {
        Self::from_arrays(plan, direction, max_batch, filter, ExecPolicy::Seq)
    }

    /// New backend with an explicit execution strategy: when `scheduled`,
    /// the plan is compiled into conflict-free layers at construction time
    /// and applied with up to `threads` spawned workers per batch.
    #[deprecated(note = "use `NativeGftBackend::with_policy` with `ExecPolicy::Seq` or \
                         `ExecPolicy::Spawn`")]
    pub fn with_schedule(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        scheduled: bool,
        threads: usize,
    ) -> Self {
        let policy = if scheduled {
            ExecPolicy::Spawn(ExecConfig::spawn().with_threads(threads))
        } else {
            ExecPolicy::Seq
        };
        Self::from_arrays(plan, direction, max_batch, filter, policy)
    }

    /// New backend on the persistent worker pool: the plan is compiled
    /// (levels + fused superstages) at construction time and every apply
    /// runs cache-blocked on the process-wide pool — no thread spawns on
    /// the request path.
    #[deprecated(note = "use `NativeGftBackend::with_policy` with `ExecPolicy::Pool`")]
    pub fn with_pool(
        plan: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        cfg: ExecConfig,
    ) -> Self {
        Self::from_arrays(plan, direction, max_batch, filter, ExecPolicy::Pool(cfg))
    }

    /// Shim body of the deprecated constructors: widen the f32 arrays to
    /// an exact G-chain (lossless) and build a plan. Panics like the old
    /// constructors did on a bad filter.
    fn from_arrays(
        arrays: PlanArrays,
        direction: TransformDirection,
        max_batch: usize,
        filter: Option<Vec<f32>>,
        policy: ExecPolicy,
    ) -> Self {
        if direction == TransformDirection::Filter {
            assert!(
                filter.as_ref().is_some_and(|h| h.len() == arrays.n),
                "filter length mismatch"
            );
        }
        // exact widening (no renormalization) keeps the shims' output
        // bitwise-identical to the old plan-arrays execution paths
        let plan = Plan::from(GChain::from_plan_exact(&arrays)).build();
        Self::with_policy(plan, direction, max_batch, filter, policy)
            .expect("validated above")
    }

    /// The shared plan this backend serves.
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// The execution policy applies run under.
    pub fn policy(&self) -> &ExecPolicy {
        &self.policy
    }

    /// `X ← diag(h) X` on the live block.
    fn scale_rows(block: &mut SignalBlock, h: &[f32]) {
        let b = block.batch;
        for (i, &hi) in h.iter().enumerate() {
            for v in &mut block.data[i * b..(i + 1) * b] {
                *v *= hi;
            }
        }
    }
}

/// The backend *is* a [`FastOperator`]: it exposes the underlying
/// operator direction-polymorphically (the serve-time
/// [`TransformDirection`] mapping — Forward ⇒ adjoint, Inverse ⇒ forward,
/// Filter ⇒ adjoint·diag(h)·forward — lives only in
/// [`Backend::forward`]).
impl FastOperator for NativeGftBackend {
    fn n(&self) -> usize {
        self.plan.n()
    }

    fn flops(&self) -> usize {
        FastOperator::flops(self.plan.as_ref())
    }

    fn apply(
        &self,
        block: &mut SignalBlock,
        dir: Direction,
        policy: &ExecPolicy,
    ) -> crate::Result<()> {
        self.plan.apply(block, dir, policy)
    }

    fn apply_vec(&self, x: &mut [f64], dir: Direction) -> crate::Result<()> {
        self.plan.apply_vec(x, dir)
    }

    fn apply_mat(&self, m: &mut crate::linalg::Mat, dir: Direction) -> crate::Result<()> {
        self.plan.apply_mat(m, dir)
    }
}

impl Backend for NativeGftBackend {
    fn n(&self) -> usize {
        self.plan.n()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        match self.direction {
            // analysis / forward GFT: x̂ = Ūᵀ x
            TransformDirection::Forward => {
                self.plan.apply(block, Direction::Adjoint, &self.policy)
            }
            // synthesis / inverse GFT: x = Ū x̂
            TransformDirection::Inverse => {
                self.plan.apply(block, Direction::Forward, &self.policy)
            }
            // spectral filter: y = Ū diag(h) Ūᵀ x
            TransformDirection::Filter => {
                let h = self.filter.as_ref().expect("checked in with_policy");
                self.plan.apply(block, Direction::Adjoint, &self.policy)?;
                Self::scale_rows(block, h);
                self.plan.apply(block, Direction::Forward, &self.policy)
            }
        }
    }

    fn name(&self) -> &str {
        match self.policy {
            ExecPolicy::Seq => "native-gft",
            ExecPolicy::Spawn(_) => "native-gft-scheduled",
            ExecPolicy::Pool(_) => "native-gft-pooled",
        }
    }
}

/// PJRT-artifact backend: executes the AOT-compiled JAX/Pallas program.
pub struct PjrtGftBackend {
    store: ArtifactStore,
    artifact: String,
    plan: PlanArrays,
    filter: Option<Vec<f32>>,
    n: usize,
    batch: usize,
}

impl PjrtGftBackend {
    /// Bind a plan to a compatible artifact from `store` (matching kind /
    /// n / batch, with plan capacity ≥ the plan length). Compiles eagerly
    /// so the request path never pays compilation.
    pub fn new(
        mut store: ArtifactStore,
        direction: TransformDirection,
        plan: PlanArrays,
        batch: usize,
        filter: Option<Vec<f32>>,
    ) -> crate::Result<Self> {
        let kind = match direction {
            TransformDirection::Forward => ArtifactKind::GftFwd,
            TransformDirection::Inverse => ArtifactKind::GftInv,
            TransformDirection::Filter => ArtifactKind::GraphFilter,
        };
        let meta = store
            .find_with_capacity(kind, plan.n, batch, plan.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact for kind={} n={} batch={batch} g≥{}",
                    kind.as_str(),
                    plan.n,
                    plan.len()
                )
            })?
            .clone();
        if kind == ArtifactKind::GraphFilter && filter.as_ref().map_or(true, |h| h.len() != plan.n)
        {
            bail!("graph_filter backend needs a length-n filter");
        }
        store.engine(&meta.name)?; // compile now
        Ok(PjrtGftBackend {
            store,
            artifact: meta.name,
            n: plan.n,
            batch,
            plan,
            filter,
        })
    }
}

impl Backend for PjrtGftBackend {
    fn n(&self) -> usize {
        self.n
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn forward(&mut self, block: &mut SignalBlock) -> crate::Result<()> {
        let engine = self.store.engine(&self.artifact)?;
        let out = engine.execute(&self.plan, block, self.filter.as_deref())?;
        *block = out;
        Ok(())
    }

    fn name(&self) -> &str {
        "pjrt-gft"
    }
}

#[cfg(test)]
#[allow(deprecated)] // the deprecated constructor shims are under test too
mod tests {
    use super::*;
    use crate::linalg::Rng64;
    use crate::transforms::{GKind, GTransform};

    fn random_plan(n: usize, g: usize, seed: u64) -> PlanArrays {
        let mut rng = Rng64::new(seed);
        let mut ch = GChain::identity(n);
        for _ in 0..g {
            let i = rng.below(n - 1);
            let j = i + 1 + rng.below(n - 1 - i);
            let th = rng.uniform_in(0.0, std::f64::consts::TAU);
            let kind = if rng.bernoulli(0.5) { GKind::Rotation } else { GKind::Reflection };
            ch.transforms.push(GTransform::new(i, j, th.cos(), th.sin(), kind));
        }
        ch.to_plan()
    }

    #[test]
    fn native_forward_then_inverse_is_identity() {
        let plan = random_plan(8, 20, 601);
        let mut fwd = NativeGftBackend::new(plan.clone(), TransformDirection::Forward, 4, None);
        let mut inv = NativeGftBackend::new(plan, TransformDirection::Inverse, 4, None);
        let mut rng = Rng64::new(602);
        let sig: Vec<f32> = (0..8).map(|_| rng.randn() as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 4]).unwrap();
        fwd.forward(&mut block).unwrap();
        inv.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn filter_all_ones_is_identity() {
        let plan = random_plan(6, 15, 603);
        let mut f = NativeGftBackend::new(
            plan,
            TransformDirection::Filter,
            2,
            Some(vec![1.0; 6]),
        );
        let sig: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let mut block = SignalBlock::from_signals(&vec![sig.clone(); 2]).unwrap();
        f.forward(&mut block).unwrap();
        for (a, b) in sig.iter().zip(block.signal(0).iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn scheduled_backend_matches_sequential() {
        let mut rng = Rng64::new(606);
        let plan = random_plan(16, 120, 605);
        let signals: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.randn() as f32).collect()).collect();
        let h: Vec<f32> = (0..16).map(|i| 1.0 / (1.0 + i as f32)).collect();
        for direction in
            [TransformDirection::Forward, TransformDirection::Inverse, TransformDirection::Filter]
        {
            let filter =
                (direction == TransformDirection::Filter).then(|| h.clone());
            let mut seq = NativeGftBackend::new(plan.clone(), direction, 6, filter.clone());
            let mut sched =
                NativeGftBackend::with_schedule(plan.clone(), direction, 6, filter, true, 4);
            assert_eq!(sched.name(), "native-gft-scheduled");
            let mut a = SignalBlock::from_signals(&signals).unwrap();
            let mut b = SignalBlock::from_signals(&signals).unwrap();
            seq.forward(&mut a).unwrap();
            sched.forward(&mut b).unwrap();
            assert_eq!(a.data, b.data, "direction {direction:?} diverged");
        }
    }

    #[test]
    fn pooled_backend_matches_sequential_bitwise() {
        // the pooled fast path must serve bit-identical answers to the
        // sequential backend in every direction (fusion only reorders
        // stages with disjoint supports)
        let mut rng = Rng64::new(608);
        let plan = random_plan(16, 400, 607);
        let signals: Vec<Vec<f32>> =
            (0..6).map(|_| (0..16).map(|_| rng.randn() as f32).collect()).collect();
        let h: Vec<f32> = (0..16).map(|i| 1.0 / (1.0 + i as f32)).collect();
        // tiny thresholds so the pooled parallel path really engages
        let cfg = ExecConfig { threads: 3, min_work: 1, layer_min_work: 1.0, tile_cols: 2 };
        for direction in
            [TransformDirection::Forward, TransformDirection::Inverse, TransformDirection::Filter]
        {
            let filter = (direction == TransformDirection::Filter).then(|| h.clone());
            let mut seq = NativeGftBackend::new(plan.clone(), direction, 6, filter.clone());
            let mut pooled =
                NativeGftBackend::with_pool(plan.clone(), direction, 6, filter, cfg.clone());
            assert_eq!(pooled.name(), "native-gft-pooled");
            let mut a = SignalBlock::from_signals(&signals).unwrap();
            let mut b = SignalBlock::from_signals(&signals).unwrap();
            seq.forward(&mut a).unwrap();
            pooled.forward(&mut b).unwrap();
            assert_eq!(a.data, b.data, "direction {direction:?} diverged");
        }
    }

    #[test]
    fn with_policy_matches_deprecated_shims_bitwise() {
        // one plan, four constructions: the new policy constructor must
        // serve exactly what each legacy shim serves
        let mut rng = Rng64::new(609);
        let arrays = random_plan(12, 200, 610);
        // widen exactly like the shims do (no renormalization)
        let chain = GChain::from_plan_exact(&arrays);
        let plan = crate::plan::Plan::from(&chain).build();
        let signals: Vec<Vec<f32>> =
            (0..5).map(|_| (0..12).map(|_| rng.randn() as f32).collect()).collect();
        let cfg = ExecConfig { threads: 2, min_work: 1, layer_min_work: 1.0, tile_cols: 2 };
        let cases: Vec<(Box<dyn Backend>, Box<dyn Backend>)> = vec![
            (
                Box::new(NativeGftBackend::new(
                    arrays.clone(),
                    TransformDirection::Forward,
                    5,
                    None,
                )),
                Box::new(
                    NativeGftBackend::with_policy(
                        plan.clone(),
                        TransformDirection::Forward,
                        5,
                        None,
                        ExecPolicy::Seq,
                    )
                    .unwrap(),
                ),
            ),
            (
                Box::new(NativeGftBackend::with_pool(
                    arrays.clone(),
                    TransformDirection::Inverse,
                    5,
                    None,
                    cfg.clone(),
                )),
                Box::new(
                    NativeGftBackend::with_policy(
                        plan.clone(),
                        TransformDirection::Inverse,
                        5,
                        None,
                        ExecPolicy::Pool(cfg.clone()),
                    )
                    .unwrap(),
                ),
            ),
        ];
        for (mut old, mut new) in cases {
            let mut a = SignalBlock::from_signals(&signals).unwrap();
            let mut b = SignalBlock::from_signals(&signals).unwrap();
            old.forward(&mut a).unwrap();
            new.forward(&mut b).unwrap();
            assert_eq!(a.data, b.data, "{} vs {} diverged", old.name(), new.name());
        }
        // T-chain plans are rejected
        let t = crate::transforms::TChain::identity(4);
        let tp = crate::plan::Plan::from(t).build();
        assert!(NativeGftBackend::with_policy(
            tp,
            TransformDirection::Forward,
            2,
            None,
            ExecPolicy::Seq
        )
        .is_err());
        // filter validation errors instead of panicking
        assert!(NativeGftBackend::with_policy(
            plan,
            TransformDirection::Filter,
            2,
            Some(vec![1.0; 3]),
            ExecPolicy::Seq
        )
        .is_err());
    }

    #[test]
    fn filter_zero_annihilates() {
        let plan = random_plan(5, 10, 604);
        let mut f = NativeGftBackend::new(
            plan,
            TransformDirection::Filter,
            1,
            Some(vec![0.0; 5]),
        );
        let mut block = SignalBlock::from_signals(&[vec![1.0, -2.0, 3.0, 0.5, 4.0]]).unwrap();
        f.forward(&mut block).unwrap();
        for v in block.signal(0) {
            assert!(v.abs() < 1e-6);
        }
    }
}
