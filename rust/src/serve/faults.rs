//! Deterministic fault injection for the serving tier.
//!
//! A **failpoint** is a named site in the serving code (the backend
//! execute step, the registry's artifact read, …) that asks this module
//! whether to misbehave before doing its real work. Faults are inert by
//! default: until something arms the layer, [`fire`] is a single relaxed
//! atomic load. Tests arm it programmatically ([`install`]); CI and
//! operators arm it through the `FASTES_FAULTS` environment variable,
//! parsed once on first use.
//!
//! Determinism: each site keeps an exact hit counter, and a
//! [`FaultPlan`] names the hits it fires on (`from`, then every
//! `every`-th hit, at most `limit` times). There is no randomness — a
//! chaos test that installs `panic@1` always panics the second batch and
//! only that batch, so its assertions are exact, not probabilistic.
//!
//! `FASTES_FAULTS` syntax: `;`-separated `site=action` clauses, where
//! `action` is `sleep:MS`, `panic`, `error:MSG`, or `trunc:BYTES`,
//! optionally followed by `@FROM` (first firing hit, default 0),
//! `+EVERY` (repeat period, default: fire once), and `xLIMIT` (max
//! fires). Example:
//!
//! ```text
//! FASTES_FAULTS="serve.backend=sleep:20@0+1;registry.load=trunc:40@0"
//! ```
//!
//! Sites currently wired: `serve.backend` (fires before every batch
//! execute — sleep/panic/error), `registry.load` (fires on every
//! registry artifact read — trunc cuts the bytes before decoding).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

use anyhow::bail;

/// What a firing failpoint does to its site.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Stall the site for this many milliseconds (slow backend).
    SleepMs(u64),
    /// Panic at the site (worker panic containment path).
    Panic,
    /// Fail the site with this error message.
    Error(String),
    /// Truncate the site's byte buffer to this length (artifact
    /// corruption path; ignored by sites that carry no bytes).
    Truncate(usize),
}

/// When a failpoint fires: hit `from`, then every `every`-th hit after
/// it (`every == 0` means fire once), at most `limit` times.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// The action taken on firing hits.
    pub action: FaultAction,
    /// First (0-based) hit that fires.
    pub from: u64,
    /// Repeat period after `from`; 0 = fire only at `from`.
    pub every: u64,
    /// Maximum number of firings (`u64::MAX` = unlimited).
    pub limit: u64,
}

impl FaultPlan {
    /// Fire on every hit, unlimited.
    pub fn always(action: FaultAction) -> Self {
        FaultPlan { action, from: 0, every: 1, limit: u64::MAX }
    }

    /// Fire exactly once, on 0-based hit `at`.
    pub fn once_at(action: FaultAction, at: u64) -> Self {
        FaultPlan { action, from: at, every: 0, limit: 1 }
    }

    fn fires_on(&self, hit: u64) -> bool {
        if hit < self.from {
            return false;
        }
        let k = hit - self.from;
        if self.every == 0 {
            k == 0
        } else {
            k % self.every == 0
        }
    }
}

struct SiteState {
    plan: FaultPlan,
    hits: u64,
    fired: u64,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn sites() -> &'static Mutex<HashMap<String, SiteState>> {
    static SITES: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
    SITES.get_or_init(|| Mutex::new(HashMap::new()))
}

fn lock_sites() -> std::sync::MutexGuard<'static, HashMap<String, SiteState>> {
    // a panic while holding the lock (impossible today, but this is the
    // chaos layer) must not wedge every later failpoint check
    sites().lock().unwrap_or_else(|e| e.into_inner())
}

/// Install (or replace) the fault plan for a site and arm the layer.
pub fn install(site: &str, plan: FaultPlan) {
    lock_sites().insert(site.to_string(), SiteState { plan, hits: 0, fired: 0 });
    ARMED.store(true, Ordering::SeqCst);
}

/// Remove every installed fault and disarm the layer (hit counters are
/// dropped too). Chaos tests call this on entry and exit so faults never
/// leak across tests.
pub fn clear() {
    lock_sites().clear();
    ARMED.store(false, Ordering::SeqCst);
}

/// Number of times `site`'s fault actually fired (0 when not installed).
pub fn fired_count(site: &str) -> u64 {
    lock_sites().get(site).map_or(0, |s| s.fired)
}

/// Ask whether the named failpoint fires on this hit. Counts the hit
/// either way. The near-universal disarmed case is one atomic load.
pub fn fire(site: &str) -> Option<FaultAction> {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("FASTES_FAULTS") {
            if !spec.trim().is_empty() {
                match install_spec(&spec) {
                    Ok(n) => eprintln!("fastes: FASTES_FAULTS armed {n} failpoint(s)"),
                    Err(e) => eprintln!("fastes: ignoring malformed FASTES_FAULTS: {e:#}"),
                }
            }
        }
    });
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = lock_sites();
    let st = g.get_mut(site)?;
    let hit = st.hits;
    st.hits += 1;
    if st.fired < st.plan.limit && st.plan.fires_on(hit) {
        st.fired += 1;
        return Some(st.plan.action.clone());
    }
    None
}

/// Parse a `FASTES_FAULTS` spec and install every clause; returns how
/// many failpoints were installed.
pub fn install_spec(spec: &str) -> crate::Result<usize> {
    let mut installed = 0;
    for clause in spec.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (site, rhs) = clause
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("fault clause {clause:?} has no '='"))?;
        install(site.trim(), parse_plan(rhs.trim())?);
        installed += 1;
    }
    Ok(installed)
}

fn parse_plan(rhs: &str) -> crate::Result<FaultPlan> {
    // action[:arg][@FROM][+EVERY][xLIMIT] — schedule suffixes may come in
    // any order after the action
    let mut action_part = rhs;
    let mut from = 0u64;
    let mut every = 0u64;
    let mut limit = 1u64;
    let mut explicit_limit = false;
    while let Some(at) = action_part.rfind(['@', '+', 'x']) {
        let (head, tail) = action_part.split_at(at);
        let num = &tail[1..];
        let Ok(v) = num.parse::<u64>() else {
            break; // not a schedule suffix (e.g. the 'x' inside a message)
        };
        match tail.as_bytes()[0] {
            b'@' => from = v,
            b'+' => every = v,
            _ => {
                limit = v;
                explicit_limit = true;
            }
        }
        action_part = head;
    }
    if every > 0 && !explicit_limit {
        limit = u64::MAX; // periodic faults default to unlimited firings
    }
    let (name, arg) = match action_part.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (action_part, None),
    };
    let action = match (name, arg) {
        ("sleep", Some(ms)) => FaultAction::SleepMs(ms.parse()?),
        ("panic", None) => FaultAction::Panic,
        ("error", Some(msg)) => FaultAction::Error(msg.to_string()),
        ("error", None) => FaultAction::Error("injected fault".to_string()),
        ("trunc", Some(len)) => FaultAction::Truncate(len.parse()?),
        _ => bail!("unknown fault action {action_part:?}"),
    };
    Ok(FaultPlan { action, from, every, limit })
}

/// Apply a fired action at a site that executes work: sleeps sleep,
/// errors return `Err`, panics panic. `Truncate` is a no-op here (it
/// only means something to byte-reading sites).
pub fn apply_exec_action(action: FaultAction) -> crate::Result<()> {
    match action {
        FaultAction::SleepMs(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        FaultAction::Panic => panic!("injected fault: backend panic"),
        FaultAction::Error(msg) => bail!("injected fault: {msg}"),
        FaultAction::Truncate(_) => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: faults are process-global; these tests use unique site names
    // so they cannot interfere with each other or with the chaos suite.

    #[test]
    fn disarmed_site_never_fires() {
        assert_eq!(fire("faults.test.unused"), None);
        assert_eq!(fired_count("faults.test.unused"), 0);
    }

    #[test]
    fn schedule_from_every_limit() {
        install(
            "faults.test.sched",
            FaultPlan { action: FaultAction::SleepMs(1), from: 1, every: 2, limit: 2 },
        );
        let fired: Vec<bool> =
            (0..8).map(|_| fire("faults.test.sched").is_some()).collect();
        // hits 1 and 3 fire (from=1, every=2), then the limit of 2 stops 5 and 7
        assert_eq!(fired, vec![false, true, false, true, false, false, false, false]);
        assert_eq!(fired_count("faults.test.sched"), 2);
        lock_sites().remove("faults.test.sched");
    }

    #[test]
    fn once_at_fires_exactly_once() {
        install("faults.test.once", FaultPlan::once_at(FaultAction::Panic, 2));
        assert_eq!(fire("faults.test.once"), None);
        assert_eq!(fire("faults.test.once"), None);
        assert_eq!(fire("faults.test.once"), Some(FaultAction::Panic));
        assert_eq!(fire("faults.test.once"), None);
        lock_sites().remove("faults.test.once");
    }

    #[test]
    fn spec_parsing_round_trips() {
        let p = parse_plan("sleep:25@3+4x5").unwrap();
        assert_eq!(p.action, FaultAction::SleepMs(25));
        assert_eq!((p.from, p.every, p.limit), (3, 4, 5));

        let p = parse_plan("panic@1").unwrap();
        assert_eq!(p.action, FaultAction::Panic);
        assert_eq!((p.from, p.every, p.limit), (1, 0, 1));

        let p = parse_plan("trunc:100").unwrap();
        assert_eq!(p.action, FaultAction::Truncate(100));
        assert_eq!((p.from, p.every, p.limit), (0, 0, 1));

        // periodic with no explicit limit = unlimited
        let p = parse_plan("error:boom+1").unwrap();
        assert_eq!(p.action, FaultAction::Error("boom".to_string()));
        assert_eq!((p.from, p.every, p.limit), (0, 1, u64::MAX));

        assert!(parse_plan("explode").is_err());
        assert!(install_spec("site-without-equals").is_err());
    }
}
