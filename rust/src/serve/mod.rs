//! Serving coordinator: batched GFT / spectral-filter serving.
//!
//! The L3 request path. Clients [`submit`](Coordinator::submit) signals;
//! the coordinator queues them on a **bounded** channel (backpressure),
//! a worker thread drains the queue into dynamic batches — up to
//! `max_batch` requests or until `batch_window` elapses since the first
//! queued request — executes the batch on a [`Backend`] (either the
//! native rust butterfly fast path or a PJRT-compiled artifact), and
//! answers each request on its own one-shot channel. Latency and batch
//! occupancy metrics are recorded for every request.
//!
//! # QoS / robustness (the hardened serving edge)
//!
//! * **Typed load shedding** — [`Coordinator::submit_with`] answers
//!   overload with a typed [`Rejected`] (`QueueFull` carries a
//!   retry-after hint) instead of blocking; expired per-request
//!   deadlines come back as `Rejected::DeadlineExceeded` *without
//!   executing*; submits racing a shutdown get `Rejected::ShuttingDown`.
//! * **Priority classes** — [`Priority::Interactive`] requests preempt
//!   [`Priority::Batch`] ones at batch-formation time (FIFO within each
//!   class), so latency-critical traffic overtakes queued analytics.
//! * **Multi-plan routing** — with a [`PlanRegistry`] attached
//!   ([`Coordinator::start_with_registry`]), requests resolve their
//!   `Arc<Plan>` **at submit time** (by checksum, or the registry's
//!   default). A hot swap ([`PlanRegistry::install_default`]) therefore
//!   never touches in-flight or queued work: those jobs hold the old
//!   `Arc` and drain on it, while every later submit runs the new plan.
//! * **Panic containment** — a backend panic fails only its own batch
//!   (each job answered with a typed backend error); the worker keeps
//!   serving. Every accepted job is answered on every code path — reply
//!   channels are never dropped silently.
//! * **Fault injection** — the worker consults the [`faults`] failpoint
//!   `serve.backend` before each batch, so the chaos suite can inject
//!   slow/panicking/erroring backends deterministically.
//!
//! Design notes: the environment's crate snapshot has no tokio, so the
//! coordinator is built directly on `std::sync::mpsc` — one OS thread
//! owns the backend (PJRT executables are not Sync), `sync_channel`
//! provides the bounded queue, and per-request one-shot replies are
//! `sync_channel(1)`. Intra-batch parallelism comes from the backend: the
//! pooled native backend ([`NativeGftBackend::with_policy`] with
//! [`ExecPolicy::Pool`](crate::plan::ExecPolicy::Pool)) executes each
//! batch on the **process-wide persistent worker pool**
//! ([`crate::transforms::global_pool`]), so one set of parked workers is
//! shared across every request and every coordinator in the process — no
//! thread is spawned on the request path.

mod backend;
pub mod faults;
mod metrics;
pub mod net;
pub mod refactor;
mod registry;

pub use backend::{Backend, NativeGftBackend, PjrtGftBackend, TransformDirection};
pub use metrics::{MetricsSnapshot, ServeMetrics, RESERVOIR_CAP};
pub use refactor::{
    refactor_and_swap, refactor_plan, RefactorJob, RefactorOptions, RefactorOutcome,
    RefactorResult, RefactorWorker,
};
pub use registry::{PlanRegistry, RegistryStats, ResidentPlanInfo};

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::ops::{SparseSpectrum, SpectralKernel, TopK};
use crate::plan::Plan;
use crate::transforms::SignalBlock;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum requests per executed batch (usually the backend batch).
    pub max_batch: usize,
    /// How long to wait for more requests after the first one arrives.
    pub batch_window: Duration,
    /// Bounded queue capacity (backpressure limit).
    pub queue_capacity: usize,
    /// Error budget (`serve --max-error ε`): refuse to route to plans
    /// whose `.fastplan` error certificate reports `rel_err > ε`, and to
    /// plans that carry no certificate at all (nothing to audit against).
    /// `None` (the default) disables the gate.
    pub max_error: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            queue_capacity: 1024,
            max_error: None,
        }
    }
}

/// Request priority class: interactive traffic preempts batch traffic at
/// batch-formation time (FIFO within each class).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-critical traffic (the default).
    #[default]
    Interactive,
    /// Throughput traffic; only runs when no interactive work is queued.
    Batch,
}

/// How a spectral request specifies its diagonal response `h`.
#[derive(Clone, Debug, PartialEq)]
pub enum ResponseSpec {
    /// Explicit per-eigenvalue response (works on any routed G-plan).
    Explicit(Vec<f64>),
    /// Analytic kernel, evaluated on the routed plan's Lemma-1 spectrum
    /// at execution time — an in-flight request therefore always runs on
    /// the spectrum of the plan it resolved at submit, even across a
    /// registry hot swap.
    Kernel(SpectralKernel),
}

/// A served spectral-filter request: one fused `Ū diag(h) Ūᵀ` apply.
#[derive(Clone, Debug, PartialEq)]
pub struct FilterSpec {
    /// The diagonal response.
    pub response: ResponseSpec,
}

impl FilterSpec {
    /// Resolve the concrete response against the routed plan.
    pub fn resolve(&self, plan: &Plan) -> crate::Result<Vec<f64>> {
        match &self.response {
            ResponseSpec::Explicit(h) => Ok(h.clone()),
            ResponseSpec::Kernel(k) => {
                let Some(s) = plan.spectrum() else {
                    bail!("routed plan carries no spectrum; kernel filters need a v2 .fastplan")
                };
                Ok(k.response(s))
            }
        }
    }
}

/// A served wavelet-analysis request: the Hammond bank at `scales`
/// wavelet scales (reply is the `(scales + 1)·n` band-major
/// concatenation, band 0 = scaling function).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WaveletSpec {
    /// Number of wavelet scales `J` (≥ 1).
    pub scales: usize,
}

/// A served top-k compression request (sparse reply).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TopKSpec {
    /// The selection rule.
    pub rule: TopK,
}

/// Which transform a request asks for, relative to the serving
/// convention: `Forward` is the analysis GFT `x̂ = Ūᵀ x`, `Adjoint` the
/// synthesis `x = Ū x̂`. The spectral kinds (`Filter` / `Wavelet` /
/// `TopK`) carry their spec in an `Arc` so queued jobs share it.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum JobOp {
    /// Analysis / forward GFT (the default).
    #[default]
    Forward,
    /// Synthesis / inverse GFT.
    Adjoint,
    /// Fused spectral filter `y = Ū diag(h) Ūᵀ x` (dense reply).
    Filter(Arc<FilterSpec>),
    /// Hammond wavelet-bank analysis (dense reply of `(J+1)·n` values).
    Wavelet(Arc<WaveletSpec>),
    /// Top-k spectral compression (sparse reply).
    TopK(Arc<TopKSpec>),
}

impl JobOp {
    /// `true` for the spectral request kinds, which need a registry-routed
    /// plan (the fixed-route backends only serve plain transforms).
    pub fn is_spectral(&self) -> bool {
        matches!(self, JobOp::Filter(_) | JobOp::Wavelet(_) | JobOp::TopK(_))
    }

    /// Batch-compatibility: two ops co-batch when they would execute the
    /// exact same computation (same kind, same spec — by pointer or by
    /// value, so re-submitted identical specs still share a batch).
    fn route_eq(&self, other: &JobOp) -> bool {
        match (self, other) {
            (JobOp::Forward, JobOp::Forward) | (JobOp::Adjoint, JobOp::Adjoint) => true,
            (JobOp::Filter(a), JobOp::Filter(b)) => Arc::ptr_eq(a, b) || a == b,
            (JobOp::Wavelet(a), JobOp::Wavelet(b)) => Arc::ptr_eq(a, b) || a == b,
            (JobOp::TopK(a), JobOp::TopK(b)) => Arc::ptr_eq(a, b) || a == b,
            _ => false,
        }
    }

    /// Submit-time validation against the resolved route, so malformed
    /// spectral requests shed as typed errors before touching the queue.
    fn validate(&self, plan: Option<&Arc<Plan>>) -> Result<(), ServeError> {
        if !self.is_spectral() {
            return Ok(());
        }
        let Some(plan) = plan else {
            return Err(ServeError::Rejected(Rejected::PlanUnavailable {
                reason: "spectral requests (filter/wavelet/topk) need a registry-routed plan"
                    .into(),
            }));
        };
        match self {
            JobOp::Forward | JobOp::Adjoint => Ok(()),
            JobOp::Filter(spec) => match &spec.response {
                ResponseSpec::Explicit(h) => {
                    if h.len() != plan.n() {
                        return Err(ServeError::Invalid(format!(
                            "filter response length {} != plan n {}",
                            h.len(),
                            plan.n()
                        )));
                    }
                    if let Some(bad) = h.iter().find(|v| !v.is_finite()) {
                        return Err(ServeError::Invalid(format!(
                            "filter response must be finite (got {bad})"
                        )));
                    }
                    Ok(())
                }
                ResponseSpec::Kernel(_) => require_spectrum(plan),
            },
            JobOp::Wavelet(spec) => {
                if spec.scales == 0 {
                    return Err(ServeError::Invalid(
                        "wavelet request needs scales >= 1".into(),
                    ));
                }
                require_spectrum(plan)
            }
            JobOp::TopK(spec) => {
                spec.rule.validate().map_err(|e| ServeError::Invalid(format!("{e:#}")))
            }
        }
    }
}

fn require_spectrum(plan: &Plan) -> Result<(), ServeError> {
    if plan.spectrum().is_some() {
        Ok(())
    } else {
        // the plan *resolved* fine — it just can't serve this request
        // kind, which is a different failure than an unresolvable route
        Err(ServeError::Rejected(Rejected::UnsupportedPlan {
            reason: "routed plan carries no spectrum (v1 artifact?); kernel-based spectral \
                     requests need a version-2 .fastplan"
                .into(),
        }))
    }
}

/// A request's answer: a dense signal (plain transforms, filters,
/// band-major wavelet stacks) or a sparse top-k spectral payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// A transformed signal (length `n`, or `(J+1)·n` for wavelet banks).
    Dense(Vec<f32>),
    /// Sparse spectral coefficients from a top-k request.
    Sparse(SparseSpectrum),
}

impl Payload {
    /// Extract the dense signal; sparse payloads become a typed error.
    pub fn into_dense(self) -> Result<Vec<f32>, ServeError> {
        match self {
            Payload::Dense(v) => Ok(v),
            Payload::Sparse(_) => Err(ServeError::Invalid(
                "request produced a sparse payload; read it via wait_detailed".into(),
            )),
        }
    }
}

/// Typed load-shedding answer: why a request was refused without (fully)
/// executing. Carried through [`ServeError::Rejected`] and mapped onto
/// wire rejection codes by the network front-end.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The bounded queue is full. `retry_after_ms` estimates when the
    /// queue will have drained — clients should back off at least this
    /// long before retrying.
    QueueFull {
        /// Suggested client backoff in milliseconds.
        retry_after_ms: u64,
    },
    /// The request's deadline expired before execution started; the
    /// backend never ran for it.
    DeadlineExceeded,
    /// The coordinator is draining for shutdown; retry against another
    /// replica.
    ShuttingDown,
    /// The requested plan could not be resolved (unknown checksum,
    /// corrupt/truncated artifact, no registry attached). Per-request:
    /// other plans keep serving.
    PlanUnavailable {
        /// Human-readable resolution failure.
        reason: String,
    },
    /// The routed plan resolved fine but cannot serve this request: it
    /// lacks a capability the request needs (e.g. a spectrum-less v1
    /// artifact asked for a kernel filter) or fails the coordinator's
    /// error budget (`--max-error`). Distinct from `PlanUnavailable` so
    /// clients don't uselessly retry an unresolvable route.
    UnsupportedPlan {
        /// Human-readable capability mismatch.
        reason: String,
    },
}

impl Rejected {
    /// Stable machine-readable code (the wire protocol's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            Rejected::QueueFull { .. } => "queue_full",
            Rejected::DeadlineExceeded => "deadline_exceeded",
            Rejected::ShuttingDown => "shutting_down",
            Rejected::PlanUnavailable { .. } => "plan_unavailable",
            Rejected::UnsupportedPlan { .. } => "unsupported_plan",
        }
    }

    /// Backoff hint, when the rejection carries one.
    pub fn retry_after_ms(&self) -> Option<u64> {
        match self {
            Rejected::QueueFull { retry_after_ms } => Some(*retry_after_ms),
            _ => None,
        }
    }
}

impl std::fmt::Display for Rejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Rejected::QueueFull { retry_after_ms } => {
                write!(f, "queue full (backpressure); retry after ~{retry_after_ms} ms")
            }
            Rejected::DeadlineExceeded => write!(f, "deadline exceeded before execution"),
            Rejected::ShuttingDown => write!(f, "coordinator is shutting down"),
            Rejected::PlanUnavailable { reason } => write!(f, "plan unavailable: {reason}"),
            Rejected::UnsupportedPlan { reason } => write!(f, "unsupported plan: {reason}"),
        }
    }
}

impl std::error::Error for Rejected {}

/// Everything that can come back instead of a transformed signal.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// Typed load shedding — see [`Rejected`].
    Rejected(Rejected),
    /// Malformed request (wrong signal length, …) — a client error.
    Invalid(String),
    /// The backend failed (or panicked) while executing the batch.
    Backend(String),
}

impl ServeError {
    /// Stable machine-readable code (the wire protocol's `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::Rejected(r) => r.code(),
            ServeError::Invalid(_) => "bad_request",
            ServeError::Backend(_) => "backend_error",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected(r) => write!(f, "rejected: {r}"),
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            ServeError::Backend(msg) => write!(f, "backend error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-request submit options for [`Coordinator::submit_with`].
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Priority class (default [`Priority::Interactive`]).
    pub priority: Priority,
    /// Absolute deadline; a request still queued past it is answered
    /// [`Rejected::DeadlineExceeded`] without executing.
    pub deadline: Option<Instant>,
    /// Route to a registry plan by content checksum (`None` = the
    /// registry default, or the backend's own plan without a registry).
    pub plan: Option<u64>,
    /// Which transform to apply (default [`JobOp::Forward`]).
    pub op: JobOp,
}

struct Job {
    signal: Vec<f32>,
    enqueued: Instant,
    deadline: Option<Instant>,
    priority: Priority,
    /// Registry-routed plan, resolved at submit time (`None` = the
    /// backend's own fixed route). In-flight work owns its `Arc`, which
    /// is what makes registry hot swaps drain-safe.
    plan: Option<Arc<Plan>>,
    op: JobOp,
    reply: SyncSender<Result<Payload, ServeError>>,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// Handle for an in-flight request.
pub struct Ticket {
    rx: Receiver<Result<Payload, ServeError>>,
}

impl Ticket {
    /// Block until the transformed signal is ready (dense replies only —
    /// top-k requests must use [`Ticket::wait_detailed`]).
    pub fn wait(self) -> crate::Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(payload)) => payload.into_dense().map_err(anyhow::Error::from),
            Ok(Err(e)) => Err(anyhow::Error::from(e)),
            Err(_) => Err(anyhow!("coordinator dropped the request")),
        }
    }

    /// Block until the reply, keeping the typed [`ServeError`] and the
    /// full [`Payload`] (the network front-end maps both onto the wire).
    pub fn wait_detailed(self) -> Result<Payload, ServeError> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(ServeError::Backend("coordinator dropped the request".into())),
        }
    }

    /// Wait at most `timeout` for the reply, so callers can't block
    /// forever on a wedged coordinator. Returns `None` on timeout — the
    /// request is still in flight and the ticket can be waited on again;
    /// a dropped coordinator comes back as `Some(Err(..))`.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<Payload, ServeError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(Err(ServeError::Backend("coordinator dropped the request".into())))
            }
        }
    }
}

/// The serving coordinator (see module docs).
pub struct Coordinator {
    tx: SyncSender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    registry: Option<Arc<PlanRegistry>>,
    config: ServeConfig,
    n: usize,
}

impl Coordinator {
    /// Start a coordinator. The backend is constructed *inside* the worker
    /// thread by `factory` — PJRT clients/executables are not `Send`, so
    /// they must never cross threads. Fails if the factory fails.
    pub fn start<F>(factory: F, config: ServeConfig) -> crate::Result<Coordinator>
    where
        F: FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static,
    {
        Self::start_with_registry(factory, config, None)
    }

    /// Start a coordinator with an attached [`PlanRegistry`]: requests
    /// resolve their plan from the registry at submit time (explicit
    /// checksum via [`SubmitOptions::plan`], else the registry default,
    /// else the backend's own route).
    pub fn start_with_registry<F>(
        factory: F,
        config: ServeConfig,
        registry: Option<Arc<PlanRegistry>>,
    ) -> crate::Result<Coordinator>
    where
        F: FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static,
    {
        assert!(config.max_batch >= 1);
        let (tx, rx) = sync_channel::<Msg>(config.queue_capacity);
        let metrics = Arc::new(ServeMetrics::new());
        let m2 = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<(usize, usize)>>(1);
        let cfg = config.clone();
        let worker = std::thread::Builder::new()
            .name("fastes-serve".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.n(), b.max_batch())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(&mut *backend, &rx, &cfg, &m2)
            })
            .expect("spawn serve worker");
        let (n, backend_batch) = match ready_rx.recv() {
            Ok(Ok(dims)) => dims,
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(e);
            }
            Err(_) => bail!("serve worker died during startup"),
        };
        if config.max_batch > backend_batch {
            bail!("max_batch {} exceeds backend capacity {backend_batch}", config.max_batch);
        }
        Ok(Coordinator { tx, worker: Some(worker), metrics, registry, config, n })
    }

    /// The default route's signal dimension.
    pub fn n(&self) -> usize {
        self.registry
            .as_ref()
            .and_then(|r| r.default_plan())
            .map_or(self.n, |p| p.n())
    }

    /// The attached plan registry, if any.
    pub fn registry(&self) -> Option<&Arc<PlanRegistry>> {
        self.registry.as_ref()
    }

    /// The `serve --max-error` budget, if set — also the refactor
    /// worker's swap-refusal threshold.
    pub fn max_error(&self) -> Option<f64> {
        self.config.max_error
    }

    /// Resolve the route a request with `opts` would execute on.
    fn resolve_route(&self, opts: &SubmitOptions) -> Result<Option<Arc<Plan>>, Rejected> {
        match (opts.plan, &self.registry) {
            (Some(key), Some(reg)) => reg
                .get(key)
                .map(Some)
                .map_err(|e| Rejected::PlanUnavailable { reason: format!("{e:#}") }),
            (Some(key), None) => Err(Rejected::PlanUnavailable {
                reason: format!(
                    "request names plan {key:016x} but this coordinator has no plan registry"
                ),
            }),
            (None, Some(reg)) => Ok(reg.default_plan()),
            (None, None) => Ok(None),
        }
    }

    /// Enforce the coordinator's error budget (`--max-error ε`) against
    /// the resolved route's `.fastplan` error certificate. Plans without
    /// a certificate are refused outright under a budget: an unmeasured
    /// plan cannot demonstrate it meets ε.
    fn check_error_budget(&self, plan: Option<&Arc<Plan>>) -> Result<(), Rejected> {
        let (Some(eps), Some(plan)) = (self.config.max_error, plan) else {
            return Ok(());
        };
        match plan.certificate() {
            None => Err(Rejected::UnsupportedPlan {
                reason: format!(
                    "coordinator enforces --max-error {eps:e} but the routed plan carries no \
                     error certificate (pre-v3 .fastplan?); re-factor with --error-budget"
                ),
            }),
            Some(cert) if !cert.meets(eps) => Err(Rejected::UnsupportedPlan {
                reason: format!(
                    "routed plan's certified relative error {:e} exceeds the --max-error \
                     budget {eps:e} (g = {})",
                    cert.rel_err, cert.g
                ),
            }),
            Some(_) => Ok(()),
        }
    }

    fn rejected(&self, r: Rejected) -> ServeError {
        self.metrics.record_rejected(&r);
        ServeError::Rejected(r)
    }

    /// Estimated milliseconds until a full queue has drained — the
    /// `QueueFull` retry-after hint (queued batches × (batch window +
    /// mean backend execution time), minimum 1 ms).
    fn retry_after_hint_ms(&self) -> u64 {
        let mean_exec_s = self.metrics.snapshot().mean_exec_s;
        let batches = self.config.queue_capacity.div_ceil(self.config.max_batch).max(1);
        let per_batch_s = self.config.batch_window.as_secs_f64() + mean_exec_s;
        ((batches as f64 * per_batch_s) * 1e3).ceil().max(1.0) as u64
    }

    /// Full-control submit: priority class, deadline, plan routing, and
    /// transform op, with **typed** load shedding — never blocks. Errors
    /// are [`ServeError`]: `Rejected` for overload/unavailability (with
    /// retry hints), `Invalid` for malformed requests.
    pub fn submit_with(
        &self,
        signal: Vec<f32>,
        opts: SubmitOptions,
    ) -> Result<Ticket, ServeError> {
        let plan = self.resolve_route(&opts).map_err(|r| self.rejected(r))?;
        self.check_error_budget(plan.as_ref()).map_err(|r| self.rejected(r))?;
        if let Err(e) = opts.op.validate(plan.as_ref()) {
            return Err(match e {
                ServeError::Rejected(r) => self.rejected(r),
                other => other,
            });
        }
        let want = plan.as_ref().map_or(self.n, |p| p.n());
        if signal.len() != want {
            return Err(ServeError::Invalid(format!(
                "signal length {} != n {want}",
                signal.len()
            )));
        }
        if opts.deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(self.rejected(Rejected::DeadlineExceeded));
        }
        let (rtx, rrx) = sync_channel(1);
        let job = Job {
            signal,
            enqueued: Instant::now(),
            deadline: opts.deadline,
            priority: opts.priority,
            plan,
            op: opts.op,
            reply: rtx,
        };
        match self.tx.try_send(Msg::Job(job)) {
            Ok(()) => Ok(Ticket { rx: rrx }),
            Err(TrySendError::Full(_)) => {
                let hint = self.retry_after_hint_ms();
                Err(self.rejected(Rejected::QueueFull { retry_after_ms: hint }))
            }
            Err(TrySendError::Disconnected(_)) => Err(self.rejected(Rejected::ShuttingDown)),
        }
    }

    /// Submit a signal; blocks while the queue is full (backpressure).
    pub fn submit(&self, signal: Vec<f32>) -> crate::Result<Ticket> {
        let opts = SubmitOptions::default();
        let plan = self.resolve_route(&opts).map_err(anyhow::Error::from)?;
        self.check_error_budget(plan.as_ref())
            .map_err(|r| anyhow::Error::from(self.rejected(r)))?;
        let want = plan.as_ref().map_or(self.n, |p| p.n());
        if signal.len() != want {
            bail!("signal length {} != n {}", signal.len(), want);
        }
        let (rtx, rrx) = sync_channel(1);
        let job = Job {
            signal,
            enqueued: Instant::now(),
            deadline: None,
            priority: Priority::Interactive,
            plan,
            op: JobOp::Forward,
            reply: rtx,
        };
        self.tx.send(Msg::Job(job)).map_err(|_| anyhow!("coordinator is shut down"))?;
        Ok(Ticket { rx: rrx })
    }

    /// Non-blocking submit; `Err` when the queue is full or closed.
    pub fn try_submit(&self, signal: Vec<f32>) -> crate::Result<Ticket> {
        self.submit_with(signal, SubmitOptions::default()).map_err(anyhow::Error::from)
    }

    /// Submit and wait. Takes the coordinator's native signal type
    /// (`f32`, like [`Coordinator::submit`] / [`Coordinator::try_submit`]
    /// — the dtypes used to disagree); for `f64` callers use the explicit
    /// conversion helper [`Coordinator::submit_blocking_f64`].
    pub fn submit_blocking(&self, signal: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.submit(signal)?.wait()
    }

    /// Explicit `f64` convenience wrapper around [`Coordinator::submit_blocking`]:
    /// narrows the signal to the `f32` wire format, widens the response.
    pub fn submit_blocking_f64(&self, signal: &[f64]) -> crate::Result<Vec<f64>> {
        let sig32: Vec<f32> = signal.iter().map(|&v| v as f32).collect();
        let out = self.submit_blocking(sig32)?;
        Ok(out.into_iter().map(|v| v as f64).collect())
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: drains queued requests, stops the worker and
    /// returns the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Batch-formation route: jobs are co-batchable only when they share the
/// resolved plan (by pointer) and an equivalent transform op
/// ([`JobOp::route_eq`] — same kind, same spec).
struct RouteKey {
    plan_ptr: usize,
    op: JobOp,
}

fn plan_ptr(j: &Job) -> usize {
    j.plan.as_ref().map_or(0, |p| Arc::as_ptr(p) as usize)
}

fn route_key(j: &Job) -> RouteKey {
    RouteKey { plan_ptr: plan_ptr(j), op: j.op.clone() }
}

impl RouteKey {
    fn matches(&self, j: &Job) -> bool {
        self.plan_ptr == plan_ptr(j) && self.op.route_eq(&j.op)
    }
}

fn expired(j: &Job) -> bool {
    j.deadline.is_some_and(|d| Instant::now() >= d)
}

fn reject(metrics: &ServeMetrics, j: Job, r: Rejected) {
    metrics.record_rejected(&r);
    let _ = j.reply.send(Err(ServeError::Rejected(r)));
}

fn stage(qi: &mut VecDeque<Job>, qb: &mut VecDeque<Job>, j: Job) {
    match j.priority {
        Priority::Interactive => qi.push_back(j),
        Priority::Batch => qb.push_back(j),
    }
}

fn same_route_count(qi: &VecDeque<Job>, qb: &VecDeque<Job>, key: &RouteKey) -> usize {
    qi.iter().chain(qb.iter()).filter(|j| key.matches(j)).count()
}

/// Move up to `max - jobs.len()` same-route jobs out of `q` (preserving
/// order); expired ones are answered `DeadlineExceeded` instead.
fn collect_route(
    q: &mut VecDeque<Job>,
    key: &RouteKey,
    max: usize,
    jobs: &mut Vec<Job>,
    metrics: &ServeMetrics,
) {
    let mut rest = VecDeque::with_capacity(q.len());
    while let Some(j) = q.pop_front() {
        if !key.matches(&j) {
            rest.push_back(j);
        } else if expired(&j) {
            reject(metrics, j, Rejected::DeadlineExceeded);
        } else if jobs.len() < max {
            jobs.push(j);
        } else {
            rest.push_back(j);
        }
    }
    *q = rest;
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(
    backend: &mut dyn Backend,
    rx: &Receiver<Msg>,
    config: &ServeConfig,
    metrics: &ServeMetrics,
) {
    let default_n = backend.n();
    metrics.set_kernel_isa(backend.kernel_isa());
    if let Some((summary, sweeps)) = backend.tuned() {
        metrics.set_tuned(summary, sweeps);
    }
    // staged jobs by priority class: the channel is drained into these so
    // interactive work can overtake queued batch work
    let mut qi: VecDeque<Job> = VecDeque::new();
    let mut qb: VecDeque<Job> = VecDeque::new();
    let mut draining = false;
    'serve: loop {
        // stage at least one job (or finish the drain)
        while qi.is_empty() && qb.is_empty() {
            if draining {
                // staged work is done; anything still in the channel
                // arrived after the shutdown marker and is answered with
                // a typed rejection rather than a dropped channel
                while let Ok(msg) = rx.try_recv() {
                    if let Msg::Job(j) = msg {
                        reject(metrics, j, Rejected::ShuttingDown);
                    }
                }
                return;
            }
            match rx.recv() {
                Ok(Msg::Job(j)) => stage(&mut qi, &mut qb, j),
                Ok(Msg::Shutdown) => draining = true,
                Err(_) => return,
            }
        }

        // head job: interactive preempts batch; expired heads are
        // answered DeadlineExceeded without executing
        let head = loop {
            match qi.pop_front().or_else(|| qb.pop_front()) {
                Some(j) if expired(&j) => reject(metrics, j, Rejected::DeadlineExceeded),
                Some(j) => break j,
                None => continue 'serve,
            }
        };
        let key = route_key(&head);

        // soak the batch window for more co-batchable arrivals
        if !draining {
            let window_end = Instant::now() + config.batch_window;
            while same_route_count(&qi, &qb, &key) + 1 < config.max_batch {
                let now = Instant::now();
                if now >= window_end {
                    break;
                }
                match rx.recv_timeout(window_end - now) {
                    Ok(Msg::Job(j)) => stage(&mut qi, &mut qb, j),
                    Ok(Msg::Shutdown) => {
                        draining = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        draining = true;
                        break;
                    }
                }
            }
        }

        // form the batch: head + same-route staged jobs, interactive first
        let mut jobs = vec![head];
        collect_route(&mut qi, &key, config.max_batch, &mut jobs, metrics);
        collect_route(&mut qb, &key, config.max_batch, &mut jobs, metrics);

        // assemble the (n, backend_batch) block, padding unused columns
        let route_plan = jobs[0].plan.clone();
        let op = jobs[0].op.clone();
        let n = route_plan.as_ref().map_or(default_n, |p| p.n());
        let batch = jobs.len();
        let mut block = SignalBlock::zeros(n, backend.max_batch());
        for (b, j) in jobs.iter().enumerate() {
            for i in 0..n {
                block.data[i * block.batch + b] = j.signal[i];
            }
        }
        let t0 = Instant::now();
        // contain backend panics: a panicking batch fails its own jobs
        // with a typed error and the worker keeps serving
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if let Some(action) = faults::fire("serve.backend") {
                faults::apply_exec_action(action)?;
            }
            match &route_plan {
                Some(p) => backend.apply_routed(p, &op, &mut block),
                None => match &op {
                    JobOp::Forward => backend.forward(&mut block).map(|()| None),
                    JobOp::Adjoint => backend.adjoint(&mut block).map(|()| None),
                    // validated out at submit time: spectral ops always
                    // carry a resolved plan
                    spectral => Err(anyhow!(
                        "spectral request {spectral:?} reached a coordinator without a plan route"
                    )),
                },
            }
        }));
        let exec_s = t0.elapsed().as_secs_f64();

        // a backend returning per-job payloads must cover every block
        // column; anything short is a backend bug answered as an error
        let outcome = match outcome {
            Ok(Ok(Some(ps))) if ps.len() < batch => Ok(Err(anyhow!(
                "backend returned {} payloads for a batch of {batch}",
                ps.len()
            ))),
            o => o,
        };

        match outcome {
            Ok(Ok(payloads)) => {
                for (b, j) in jobs.into_iter().enumerate() {
                    let out = match &payloads {
                        Some(ps) => ps[b].clone(),
                        None => Payload::Dense(block.signal(b)),
                    };
                    let latency = j.enqueued.elapsed().as_secs_f64();
                    metrics.record(latency, exec_s, batch);
                    let _ = j.reply.send(Ok(out));
                }
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                for j in jobs.into_iter() {
                    metrics.record_error();
                    let _ = j.reply.send(Err(ServeError::Backend(msg.clone())));
                }
            }
            Err(payload) => {
                metrics.record_panic();
                let msg = format!("backend panicked: {}", panic_message(payload));
                for j in jobs.into_iter() {
                    metrics.record_error();
                    let _ = j.reply.send(Err(ServeError::Backend(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ExecPolicy, Plan};
    use crate::transforms::GChain;

    /// Identity backend through the modern constructor.
    fn identity_backend(n: usize, max_batch: usize) -> crate::Result<Box<dyn Backend>> {
        let plan = Plan::from(GChain::identity(n)).build();
        Ok(Box::new(NativeGftBackend::with_policy(
            plan,
            TransformDirection::Forward,
            max_batch,
            None,
            ExecPolicy::Seq,
        )?) as Box<dyn Backend>)
    }

    /// Backend that sleeps `ms` per batch (queue-pressure tests).
    struct Slow {
        n: usize,
        ms: u64,
    }
    impl Backend for Slow {
        fn n(&self) -> usize {
            self.n
        }
        fn max_batch(&self) -> usize {
            1
        }
        fn forward(&mut self, _b: &mut SignalBlock) -> crate::Result<()> {
            std::thread::sleep(Duration::from_millis(self.ms));
            Ok(())
        }
        fn name(&self) -> &str {
            "slow"
        }
    }

    #[test]
    fn identity_roundtrip() {
        let coord =
            Coordinator::start(|| identity_backend(4, 8), ServeConfig::default()).unwrap();
        let sig = vec![1.0f32, 2.0, 3.0, 4.0];
        let out = coord.submit(sig.clone()).unwrap().wait().unwrap();
        assert_eq!(out, sig);
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn submit_blocking_agrees_with_submit_and_f64_helper() {
        // regression: submit_blocking used to take Vec<f64> while
        // submit/try_submit took Vec<f32> — the signal type is now f32
        // everywhere, with an explicit f64 conversion helper
        let coord =
            Coordinator::start(|| identity_backend(3, 4), ServeConfig::default()).unwrap();
        let sig = vec![0.5f32, -1.25, 3.0];
        let a = coord.submit(sig.clone()).unwrap().wait().unwrap();
        let b = coord.submit_blocking(sig.clone()).unwrap();
        assert_eq!(a, b, "submit_blocking must match submit().wait()");
        let sig64 = vec![0.5f64, -1.25, 3.0];
        let c = coord.submit_blocking_f64(&sig64).unwrap();
        assert_eq!(c, sig64, "identity round-trip through the f64 helper");
        coord.shutdown();
    }

    #[test]
    fn many_requests_all_answered_in_order_of_submission() {
        let coord = Coordinator::start(
            || identity_backend(3, 4),
            ServeConfig { max_batch: 4, ..Default::default() },
        )
        .unwrap();
        let tickets: Vec<_> = (0..40)
            .map(|k| coord.submit(vec![k as f32, 0.0, 0.0]).unwrap())
            .collect();
        for (k, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap();
            assert_eq!(out[0], k as f32);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 40);
        assert!(m.mean_batch >= 1.0);
        assert!(m.max_batch_seen <= 4);
    }

    #[test]
    fn rejects_wrong_length() {
        let coord =
            Coordinator::start(|| identity_backend(4, 8), ServeConfig::default()).unwrap();
        assert!(coord.submit(vec![0.0; 3]).is_err());
        assert!(coord.submit_blocking(vec![0.0; 5]).is_err());
        match coord.submit_with(vec![0.0; 3], SubmitOptions::default()) {
            Err(ServeError::Invalid(msg)) => assert!(msg.contains("signal length"), "{msg}"),
            other => panic!("want Invalid, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn try_submit_backpressure() {
        // a slow backend + capacity-1 queue must trigger Full
        let coord = Coordinator::start(
            || Ok(Box::new(Slow { n: 2, ms: 30 }) as Box<dyn Backend>),
            ServeConfig { max_batch: 1, queue_capacity: 1, ..Default::default() },
        )
        .unwrap();
        // flood; at least one try_submit must fail with backpressure
        let mut saw_full = false;
        let mut tickets = Vec::new();
        for _ in 0..20 {
            match coord.try_submit(vec![0.0, 0.0]) {
                Ok(t) => tickets.push(t),
                Err(_) => saw_full = true,
            }
        }
        assert!(saw_full, "expected backpressure");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn queue_full_rejection_is_typed_with_retry_hint() {
        let coord = Coordinator::start(
            || Ok(Box::new(Slow { n: 2, ms: 30 }) as Box<dyn Backend>),
            ServeConfig { max_batch: 1, queue_capacity: 1, ..Default::default() },
        )
        .unwrap();
        let mut tickets = Vec::new();
        let mut rejection = None;
        for _ in 0..20 {
            match coord.submit_with(vec![0.0, 0.0], SubmitOptions::default()) {
                Ok(t) => tickets.push(t),
                Err(ServeError::Rejected(r)) => {
                    rejection = Some(r);
                    break;
                }
                Err(other) => panic!("unexpected error class: {other}"),
            }
        }
        let r = rejection.expect("capacity-1 queue must shed load");
        assert_eq!(r.code(), "queue_full");
        assert!(r.retry_after_ms().unwrap() >= 1, "hint must be actionable");
        for t in tickets {
            t.wait().unwrap();
        }
        let m = coord.shutdown();
        assert!(m.rejected_queue_full >= 1);
        assert_eq!(m.rejected, m.rejected_queue_full);
    }

    #[test]
    fn already_expired_deadline_is_rejected_at_submit() {
        let coord =
            Coordinator::start(|| identity_backend(2, 4), ServeConfig::default()).unwrap();
        let opts = SubmitOptions {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..Default::default()
        };
        match coord.submit_with(vec![1.0, 2.0], opts) {
            Err(ServeError::Rejected(Rejected::DeadlineExceeded)) => {}
            other => panic!("want DeadlineExceeded, got {:?}", other.map(|_| ())),
        }
        let m = coord.shutdown();
        assert_eq!(m.rejected_deadline, 1);
        assert_eq!(m.completed, 0, "expired request must never execute");
    }

    #[test]
    fn interactive_preempts_queued_batch_traffic() {
        // hold the worker busy, queue batch-class work, then an
        // interactive request: the interactive one must be answered
        // before the earlier-submitted batch job
        let coord = Coordinator::start(
            || Ok(Box::new(Slow { n: 2, ms: 60 }) as Box<dyn Backend>),
            ServeConfig { max_batch: 1, ..Default::default() },
        )
        .unwrap();
        let head = coord.submit(vec![0.0, 0.0]).unwrap(); // occupies the worker
        let batch = coord
            .submit_with(
                vec![1.0, 1.0],
                SubmitOptions { priority: Priority::Batch, ..Default::default() },
            )
            .unwrap();
        let interactive = coord.submit_with(vec![2.0, 2.0], SubmitOptions::default()).unwrap();
        head.wait().unwrap();
        interactive.wait().unwrap();
        // the batch job runs one 60 ms service slot after the
        // interactive one, so it cannot have been answered yet
        assert!(
            batch.wait_timeout(Duration::ZERO).is_none(),
            "batch-class job must not be answered before interactive traffic"
        );
        assert!(batch.wait_timeout(Duration::from_secs(10)).unwrap().is_ok());
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains() {
        let coord = Coordinator::start(
            || identity_backend(2, 4),
            ServeConfig { max_batch: 4, ..Default::default() },
        )
        .unwrap();
        let t1 = coord.submit(vec![5.0, 6.0]).unwrap();
        let m = coord.shutdown();
        assert!(m.completed >= 1);
        assert_eq!(t1.wait().unwrap(), vec![5.0, 6.0]);
    }

    #[test]
    fn wait_timeout_covers_timeout_late_reply_and_dropped_sender() {
        // timeout + late reply against a real (slow) coordinator
        let coord = Coordinator::start(
            || Ok(Box::new(Slow { n: 2, ms: 50 }) as Box<dyn Backend>),
            ServeConfig { max_batch: 1, ..Default::default() },
        )
        .unwrap();
        let t = coord.submit(vec![1.0, 2.0]).unwrap();
        assert!(
            t.wait_timeout(Duration::from_millis(1)).is_none(),
            "50 ms batch cannot be done after 1 ms"
        );
        // the reply arrives late — a second wait on the same ticket gets it
        let late = t.wait_timeout(Duration::from_secs(10)).expect("must complete");
        assert_eq!(late.unwrap(), Payload::Dense(vec![1.0, 2.0]));
        coord.shutdown();

        // dropped sender: the reply channel dies without an answer
        let (tx, rx) = sync_channel::<Result<Payload, ServeError>>(1);
        let ticket = Ticket { rx };
        drop(tx);
        match ticket.wait_timeout(Duration::from_millis(1)) {
            Some(Err(ServeError::Backend(msg))) => assert!(msg.contains("dropped"), "{msg}"),
            other => panic!("want dropped-sender error, got {:?}", other.map(|r| r.map(|_| ()))),
        }
    }

    fn spectral_fixture(
        n: usize,
        seed: u64,
        with_spectrum: bool,
    ) -> (Arc<Plan>, Arc<PlanRegistry>, Coordinator, crate::linalg::Rng64) {
        use crate::cli::figures::random_gplan;
        let mut rng = crate::linalg::Rng64::new(seed);
        let ch = random_gplan(n, 5 * n, &mut rng);
        let mut builder = Plan::from(&ch);
        if with_spectrum {
            let spec: Vec<f64> = (0..n).map(|_| rng.randn().abs() + 0.1).collect();
            builder = builder.spectrum(spec);
        }
        let plan = builder.build();
        let registry = Arc::new(PlanRegistry::new(4));
        registry.install_default(Arc::clone(&plan));
        let backend_plan = Arc::clone(&plan);
        let coord = Coordinator::start_with_registry(
            move || {
                Ok(Box::new(NativeGftBackend::with_policy(
                    backend_plan,
                    TransformDirection::Forward,
                    4,
                    None,
                    ExecPolicy::Seq,
                )?) as Box<dyn Backend>)
            },
            ServeConfig::default(),
            Some(Arc::clone(&registry)),
        )
        .unwrap();
        (plan, registry, coord, rng)
    }

    #[test]
    fn served_spectral_requests_match_local_references_bitwise() {
        use crate::ops::{FilterOp, WaveletBank};
        use crate::plan::Direction;
        let n = 11;
        let (plan, _registry, coord, mut rng) = spectral_fixture(n, 7201, true);
        let sig: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        let block = SignalBlock::from_signals(&[sig.clone()]).unwrap();

        // filter: the served reply is bitwise the fused FilterOp answer
        let h: Vec<f64> = (0..n).map(|_| rng.randn()).collect();
        let op = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(h.clone()),
        }));
        let got = coord
            .submit_with(sig.clone(), SubmitOptions { op, ..Default::default() })
            .unwrap()
            .wait_detailed()
            .unwrap();
        let fop = FilterOp::new(Arc::clone(&plan), h).unwrap();
        let mut want = block.clone();
        fop.apply(&mut want, Direction::Forward, &ExecPolicy::Seq).unwrap();
        assert_eq!(got, Payload::Dense(want.signal(0)));

        // kernel-based filter resolves against the routed plan's spectrum
        let kop = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Kernel(SpectralKernel::Heat { t: 0.4 }),
        }));
        let got = coord
            .submit_with(sig.clone(), SubmitOptions { op: kop, ..Default::default() })
            .unwrap()
            .wait_detailed()
            .unwrap();
        let kf = FilterOp::from_kernel(Arc::clone(&plan), &SpectralKernel::Heat { t: 0.4 })
            .unwrap();
        let mut want = block.clone();
        kf.apply(&mut want, Direction::Forward, &ExecPolicy::Seq).unwrap();
        assert_eq!(got, Payload::Dense(want.signal(0)));

        // wavelet: band-major stack of the shared-prefix bank
        let wop = JobOp::Wavelet(Arc::new(WaveletSpec { scales: 2 }));
        let got = coord
            .submit_with(sig.clone(), SubmitOptions { op: wop, ..Default::default() })
            .unwrap()
            .wait_detailed()
            .unwrap();
        let bank = WaveletBank::hammond(Arc::clone(&plan), 2).unwrap();
        let bands = bank.analyze(&block, &ExecPolicy::Seq).unwrap();
        let stacked: Vec<f32> = bands.iter().flat_map(|b| b.signal(0)).collect();
        assert_eq!(got, Payload::Dense(stacked));

        // top-k: sparse payload of the plan's spectral coefficients
        let top = JobOp::TopK(Arc::new(TopKSpec { rule: TopK::k(3) }));
        let got = coord
            .submit_with(sig.clone(), SubmitOptions { op: top, ..Default::default() })
            .unwrap()
            .wait_detailed()
            .unwrap();
        let mut want = TopK::k(3)
            .compress_spectral(&plan, &block, &ExecPolicy::Seq)
            .unwrap();
        assert_eq!(got, Payload::Sparse(want.remove(0)));
        // dense-only wait() refuses sparse payloads with a typed error
        let top = JobOp::TopK(Arc::new(TopKSpec { rule: TopK::k(3) }));
        let err = coord
            .submit_with(sig, SubmitOptions { op: top, ..Default::default() })
            .unwrap()
            .wait()
            .unwrap_err();
        assert!(format!("{err:#}").contains("sparse"), "{err:#}");

        let m = coord.shutdown();
        assert!(m.completed >= 5);
    }

    #[test]
    fn spectral_requests_validate_at_submit_time() {
        // no registry at all → PlanUnavailable before anything queues
        let coord =
            Coordinator::start(|| identity_backend(4, 8), ServeConfig::default()).unwrap();
        let op = JobOp::TopK(Arc::new(TopKSpec { rule: TopK::k(2) }));
        match coord.submit_with(vec![0.0; 4], SubmitOptions { op, ..Default::default() }) {
            Err(ServeError::Rejected(Rejected::PlanUnavailable { .. })) => {}
            other => panic!("want PlanUnavailable, got {:?}", other.map(|_| ())),
        }
        coord.shutdown();

        // spectrum-free routed plan: kernel filters and wavelets are
        // rejected as *unsupported* (the route resolved — it just can't
        // serve the request kind), explicit-response filters still work
        let n = 6;
        let (_plan, _registry, coord, mut rng) = spectral_fixture(n, 7202, false);
        let sig: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        let kop = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Kernel(SpectralKernel::Heat { t: 0.4 }),
        }));
        match coord.submit_with(sig.clone(), SubmitOptions { op: kop, ..Default::default() }) {
            Err(ServeError::Rejected(r @ Rejected::UnsupportedPlan { .. })) => {
                assert_eq!(r.code(), "unsupported_plan");
                assert!(format!("{r}").contains("spectrum"), "{r}");
            }
            other => panic!("want UnsupportedPlan, got {:?}", other.map(|_| ())),
        }
        let wop = JobOp::Wavelet(Arc::new(WaveletSpec { scales: 2 }));
        assert!(matches!(
            coord.submit_with(sig.clone(), SubmitOptions { op: wop, ..Default::default() }),
            Err(ServeError::Rejected(Rejected::UnsupportedPlan { .. }))
        ));
        // malformed specs are client errors, not rejections
        let bad_len = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(vec![1.0; n + 1]),
        }));
        assert!(matches!(
            coord.submit_with(sig.clone(), SubmitOptions { op: bad_len, ..Default::default() }),
            Err(ServeError::Invalid(_))
        ));
        let zero_scales = JobOp::Wavelet(Arc::new(WaveletSpec { scales: 0 }));
        assert!(matches!(
            coord
                .submit_with(sig.clone(), SubmitOptions { op: zero_scales, ..Default::default() }),
            Err(ServeError::Invalid(_))
        ));
        let unbounded = JobOp::TopK(Arc::new(TopKSpec { rule: TopK { k: 0, threshold: 0.0 } }));
        assert!(matches!(
            coord.submit_with(sig.clone(), SubmitOptions { op: unbounded, ..Default::default() }),
            Err(ServeError::Invalid(_))
        ));
        // explicit responses never need a spectrum
        let ok = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(vec![0.5; n]),
        }));
        coord
            .submit_with(sig, SubmitOptions { op: ok, ..Default::default() })
            .unwrap()
            .wait()
            .unwrap();
        let m = coord.shutdown();
        assert_eq!(m.rejected_unsupported_plan, 2, "kernel filter + wavelet");
    }

    #[test]
    fn max_error_budget_gates_routing_on_the_certificate() {
        use crate::linalg::Mat;
        use crate::transforms::certify_g;
        let n = 5;
        let mut rng = crate::linalg::Rng64::new(7301);
        let ch = crate::cli::figures::random_gplan(n, 4 * n, &mut rng);
        let spec: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
        // a deliberately wrong target makes the certified error non-zero
        let target = Mat::randn(n, n, &mut rng);
        let target = &target + &target.transpose();
        let cert = certify_g(&ch, &target, &spec, &[1.0]);
        assert!(cert.rel_err > 0.0);
        let certified = Plan::from(&ch).spectrum(spec.clone()).certificate(cert.clone()).build();
        let uncertified = Plan::from(&ch).spectrum(spec).build();

        let start = |plan: Arc<Plan>, max_error: Option<f64>| {
            let registry = Arc::new(PlanRegistry::new(4));
            registry.install_default(Arc::clone(&plan));
            let backend_plan = Arc::clone(&plan);
            Coordinator::start_with_registry(
                move || {
                    Ok(Box::new(NativeGftBackend::with_policy(
                        backend_plan,
                        TransformDirection::Forward,
                        4,
                        None,
                        ExecPolicy::Seq,
                    )?) as Box<dyn Backend>)
                },
                ServeConfig { max_error, ..Default::default() },
                Some(registry),
            )
            .unwrap()
        };
        let sig = vec![1.0f32; n];

        // no budget: both plans route
        let coord = start(Arc::clone(&uncertified), None);
        coord.submit_with(sig.clone(), SubmitOptions::default()).unwrap().wait().unwrap();
        coord.shutdown();

        // budget + uncertified plan: refused with the certificate reason
        let coord = start(uncertified, Some(0.5));
        match coord.submit_with(sig.clone(), SubmitOptions::default()) {
            Err(ServeError::Rejected(r @ Rejected::UnsupportedPlan { .. })) => {
                assert!(format!("{r}").contains("no error certificate"), "{r}");
            }
            other => panic!("want UnsupportedPlan, got {:?}", other.map(|_| ())),
        }
        // the blocking submit path enforces the same gate
        assert!(coord.submit(sig.clone()).is_err());
        let m = coord.shutdown();
        assert_eq!(m.rejected_unsupported_plan, 2);

        // budget tighter than the certified error: refused, naming both
        let tight = cert.rel_err / 2.0;
        let coord = start(Arc::clone(&certified), Some(tight));
        match coord.submit_with(sig.clone(), SubmitOptions::default()) {
            Err(ServeError::Rejected(r @ Rejected::UnsupportedPlan { .. })) => {
                let msg = format!("{r}");
                assert!(msg.contains("exceeds"), "{msg}");
                assert_eq!(r.retry_after_ms(), None, "capability mismatch has no backoff");
            }
            other => panic!("want UnsupportedPlan, got {:?}", other.map(|_| ())),
        }
        coord.shutdown();

        // budget looser than the certified error: serves normally
        let coord = start(certified, Some(cert.rel_err * 2.0));
        coord.submit_with(sig, SubmitOptions::default()).unwrap().wait().unwrap();
        let m = coord.shutdown();
        assert_eq!(m.rejected_unsupported_plan, 0);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn identical_filter_specs_share_a_batch_route() {
        // two separately-built but equal specs must co-batch (route_eq
        // falls back to value equality when the Arcs differ)
        let a = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(vec![1.0, 2.0]),
        }));
        let b = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(vec![1.0, 2.0]),
        }));
        let c = JobOp::Filter(Arc::new(FilterSpec {
            response: ResponseSpec::Explicit(vec![1.0, 3.0]),
        }));
        assert!(a.route_eq(&b));
        assert!(!a.route_eq(&c));
        assert!(!a.route_eq(&JobOp::Forward));
        assert!(JobOp::Forward.route_eq(&JobOp::Forward));
        assert!(!JobOp::Forward.route_eq(&JobOp::Adjoint));
    }
}
