//! Serving coordinator: batched GFT / spectral-filter serving.
//!
//! The L3 request path. Clients [`submit`](Coordinator::submit) signals;
//! the coordinator queues them on a **bounded** channel (backpressure),
//! a worker thread drains the queue into dynamic batches — up to
//! `max_batch` requests or until `batch_window` elapses since the first
//! queued request — executes the batch on a [`Backend`] (either the
//! native rust butterfly fast path or a PJRT-compiled artifact), and
//! answers each request on its own one-shot channel. Latency and batch
//! occupancy metrics are recorded for every request.
//!
//! Design notes: the environment's crate snapshot has no tokio, so the
//! coordinator is built directly on `std::sync::mpsc` — one OS thread
//! owns the backend (PJRT executables are not Sync), `sync_channel`
//! provides the bounded queue, and per-request one-shot replies are
//! `sync_channel(1)`. Intra-batch parallelism comes from the backend: the
//! pooled native backend ([`NativeGftBackend::with_policy`] with
//! [`ExecPolicy::Pool`](crate::plan::ExecPolicy::Pool)) executes each
//! batch on the **process-wide persistent worker pool**
//! ([`crate::transforms::global_pool`]), so one set of parked workers is
//! shared across every request and every coordinator in the process — no
//! thread is spawned on the request path.

mod backend;
mod metrics;

pub use backend::{Backend, NativeGftBackend, PjrtGftBackend, TransformDirection};
pub use metrics::{MetricsSnapshot, ServeMetrics};

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use crate::transforms::SignalBlock;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum requests per executed batch (usually the backend batch).
    pub max_batch: usize,
    /// How long to wait for more requests after the first one arrives.
    pub batch_window: Duration,
    /// Bounded queue capacity (backpressure limit).
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            queue_capacity: 1024,
        }
    }
}

struct Job {
    signal: Vec<f32>,
    enqueued: Instant,
    reply: SyncSender<crate::Result<Vec<f32>>>,
}

enum Msg {
    Job(Job),
    Shutdown,
}

/// Handle for an in-flight request.
pub struct Ticket {
    rx: Receiver<crate::Result<Vec<f32>>>,
}

impl Ticket {
    /// Block until the transformed signal is ready.
    pub fn wait(self) -> crate::Result<Vec<f32>> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }
}

/// The serving coordinator (see module docs).
pub struct Coordinator {
    tx: SyncSender<Msg>,
    worker: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    n: usize,
}

impl Coordinator {
    /// Start a coordinator. The backend is constructed *inside* the worker
    /// thread by `factory` — PJRT clients/executables are not `Send`, so
    /// they must never cross threads. Fails if the factory fails.
    pub fn start<F>(factory: F, config: ServeConfig) -> crate::Result<Coordinator>
    where
        F: FnOnce() -> crate::Result<Box<dyn Backend>> + Send + 'static,
    {
        assert!(config.max_batch >= 1);
        let (tx, rx) = sync_channel::<Msg>(config.queue_capacity);
        let metrics = Arc::new(ServeMetrics::new());
        let m2 = Arc::clone(&metrics);
        let (ready_tx, ready_rx) = sync_channel::<crate::Result<(usize, usize)>>(1);
        let cfg = config.clone();
        let worker = std::thread::Builder::new()
            .name("fastes-serve".into())
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.n(), b.max_batch())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                worker_loop(&mut *backend, &rx, &cfg, &m2)
            })
            .expect("spawn serve worker");
        let (n, backend_batch) = match ready_rx.recv() {
            Ok(Ok(dims)) => dims,
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(e);
            }
            Err(_) => bail!("serve worker died during startup"),
        };
        if config.max_batch > backend_batch {
            bail!("max_batch {} exceeds backend capacity {backend_batch}", config.max_batch);
        }
        Ok(Coordinator { tx, worker: Some(worker), metrics, n })
    }

    /// Submit a signal; blocks while the queue is full (backpressure).
    pub fn submit(&self, signal: Vec<f32>) -> crate::Result<Ticket> {
        if signal.len() != self.n {
            bail!("signal length {} != n {}", signal.len(), self.n);
        }
        let (rtx, rrx) = sync_channel(1);
        self.tx
            .send(Msg::Job(Job { signal, enqueued: Instant::now(), reply: rtx }))
            .map_err(|_| anyhow!("coordinator is shut down"))?;
        Ok(Ticket { rx: rrx })
    }

    /// Non-blocking submit; `Err` when the queue is full or closed.
    pub fn try_submit(&self, signal: Vec<f32>) -> crate::Result<Ticket> {
        if signal.len() != self.n {
            bail!("signal length {} != n {}", signal.len(), self.n);
        }
        let (rtx, rrx) = sync_channel(1);
        match self.tx.try_send(Msg::Job(Job { signal, enqueued: Instant::now(), reply: rtx })) {
            Ok(()) => Ok(Ticket { rx: rrx }),
            Err(TrySendError::Full(_)) => bail!("queue full (backpressure)"),
            Err(TrySendError::Disconnected(_)) => bail!("coordinator is shut down"),
        }
    }

    /// Submit and wait. Takes the coordinator's native signal type
    /// (`f32`, like [`Coordinator::submit`] / [`Coordinator::try_submit`]
    /// — the dtypes used to disagree); for `f64` callers use the explicit
    /// conversion helper [`Coordinator::submit_blocking_f64`].
    pub fn submit_blocking(&self, signal: Vec<f32>) -> crate::Result<Vec<f32>> {
        self.submit(signal)?.wait()
    }

    /// Explicit `f64` convenience wrapper around [`Coordinator::submit_blocking`]:
    /// narrows the signal to the `f32` wire format, widens the response.
    pub fn submit_blocking_f64(&self, signal: &[f64]) -> crate::Result<Vec<f64>> {
        let sig32: Vec<f32> = signal.iter().map(|&v| v as f32).collect();
        let out = self.submit_blocking(sig32)?;
        Ok(out.into_iter().map(|v| v as f64).collect())
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: drains queued requests, stops the worker and
    /// returns the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    backend: &mut dyn Backend,
    rx: &Receiver<Msg>,
    config: &ServeConfig,
    metrics: &ServeMetrics,
) {
    let n = backend.n();
    metrics.set_kernel_isa(backend.kernel_isa());
    if let Some((summary, sweeps)) = backend.tuned() {
        metrics.set_tuned(summary, sweeps);
    }
    loop {
        // wait for the first request of the batch
        let first = match rx.recv() {
            Ok(Msg::Job(j)) => j,
            Ok(Msg::Shutdown) | Err(_) => return,
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + config.batch_window;
        let mut shutdown_after = false;
        while jobs.len() < config.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Job(j)) => jobs.push(j),
                Ok(Msg::Shutdown) => {
                    shutdown_after = true;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    shutdown_after = true;
                    break;
                }
            }
        }

        // assemble the (n, backend_batch) block, padding unused columns
        let batch = jobs.len();
        let mut block = SignalBlock::zeros(n, backend.max_batch());
        for (b, j) in jobs.iter().enumerate() {
            for i in 0..n {
                block.data[i * block.batch + b] = j.signal[i];
            }
        }
        let t0 = Instant::now();
        let result = backend.forward(&mut block);
        let exec_s = t0.elapsed().as_secs_f64();

        match result {
            Ok(()) => {
                for (b, j) in jobs.into_iter().enumerate() {
                    let out = block.signal(b);
                    let latency = j.enqueued.elapsed().as_secs_f64();
                    metrics.record(latency, exec_s, batch);
                    let _ = j.reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("backend error: {e:#}");
                for j in jobs.into_iter() {
                    metrics.record_error();
                    let _ = j.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
        if shutdown_after {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::{ExecPolicy, Plan};
    use crate::transforms::GChain;

    /// Identity backend through the modern constructor.
    fn identity_backend(n: usize, max_batch: usize) -> crate::Result<Box<dyn Backend>> {
        let plan = Plan::from(GChain::identity(n)).build();
        Ok(Box::new(NativeGftBackend::with_policy(
            plan,
            TransformDirection::Forward,
            max_batch,
            None,
            ExecPolicy::Seq,
        )?) as Box<dyn Backend>)
    }

    #[test]
    fn identity_roundtrip() {
        let coord =
            Coordinator::start(|| identity_backend(4, 8), ServeConfig::default()).unwrap();
        let sig = vec![1.0f32, 2.0, 3.0, 4.0];
        let out = coord.submit(sig.clone()).unwrap().wait().unwrap();
        assert_eq!(out, sig);
        let m = coord.shutdown();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn submit_blocking_agrees_with_submit_and_f64_helper() {
        // regression: submit_blocking used to take Vec<f64> while
        // submit/try_submit took Vec<f32> — the signal type is now f32
        // everywhere, with an explicit f64 conversion helper
        let coord =
            Coordinator::start(|| identity_backend(3, 4), ServeConfig::default()).unwrap();
        let sig = vec![0.5f32, -1.25, 3.0];
        let a = coord.submit(sig.clone()).unwrap().wait().unwrap();
        let b = coord.submit_blocking(sig.clone()).unwrap();
        assert_eq!(a, b, "submit_blocking must match submit().wait()");
        let sig64 = vec![0.5f64, -1.25, 3.0];
        let c = coord.submit_blocking_f64(&sig64).unwrap();
        assert_eq!(c, sig64, "identity round-trip through the f64 helper");
        coord.shutdown();
    }

    #[test]
    fn many_requests_all_answered_in_order_of_submission() {
        let coord = Coordinator::start(
            || identity_backend(3, 4),
            ServeConfig { max_batch: 4, ..Default::default() },
        )
        .unwrap();
        let tickets: Vec<_> = (0..40)
            .map(|k| coord.submit(vec![k as f32, 0.0, 0.0]).unwrap())
            .collect();
        for (k, t) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap();
            assert_eq!(out[0], k as f32);
        }
        let m = coord.shutdown();
        assert_eq!(m.completed, 40);
        assert!(m.mean_batch >= 1.0);
        assert!(m.max_batch_seen <= 4);
    }

    #[test]
    fn rejects_wrong_length() {
        let coord =
            Coordinator::start(|| identity_backend(4, 8), ServeConfig::default()).unwrap();
        assert!(coord.submit(vec![0.0; 3]).is_err());
        assert!(coord.submit_blocking(vec![0.0; 5]).is_err());
    }

    #[test]
    fn try_submit_backpressure() {
        // a slow backend + capacity-1 queue must trigger Full
        struct Slow;
        impl Backend for Slow {
            fn n(&self) -> usize {
                2
            }
            fn max_batch(&self) -> usize {
                1
            }
            fn forward(&mut self, _b: &mut SignalBlock) -> crate::Result<()> {
                std::thread::sleep(Duration::from_millis(30));
                Ok(())
            }
            fn name(&self) -> &str {
                "slow"
            }
        }
        let coord = Coordinator::start(
            || Ok(Box::new(Slow) as Box<dyn Backend>),
            ServeConfig { max_batch: 1, queue_capacity: 1, ..Default::default() },
        )
        .unwrap();
        // flood; at least one try_submit must fail with backpressure
        let mut saw_full = false;
        let mut tickets = Vec::new();
        for _ in 0..20 {
            match coord.try_submit(vec![0.0, 0.0]) {
                Ok(t) => tickets.push(t),
                Err(_) => saw_full = true,
            }
        }
        assert!(saw_full, "expected backpressure");
        for t in tickets {
            t.wait().unwrap();
        }
    }

    #[test]
    fn shutdown_drains() {
        let coord = Coordinator::start(
            || identity_backend(2, 4),
            ServeConfig { max_batch: 4, ..Default::default() },
        )
        .unwrap();
        let t1 = coord.submit(vec![5.0, 6.0]).unwrap();
        let m = coord.shutdown();
        assert!(m.completed >= 1);
        assert_eq!(t1.wait().unwrap(), vec![5.0, 6.0]);
    }
}
