//! Serving metrics: latency samples, batch occupancy, error counts.
//!
//! Memory is **bounded**: latency samples feed a fixed-size reservoir
//! (Algorithm R with a deterministic LCG, so a given record sequence
//! always keeps the same sample set), while means, counts and maxima are
//! exact running aggregates. A coordinator that serves for months holds
//! [`RESERVOIR_CAP`] `f64`s, not one per request — the seed version kept
//! three unbounded `Vec`s and grew without limit under sustained traffic.

use std::sync::Mutex;

use super::Rejected;

/// Latency samples kept for the percentile estimates. Up to this many
/// requests the percentiles are exact; beyond it they are uniform
/// reservoir estimates (standard error ≈ 0.8% at p50).
pub const RESERVOIR_CAP: usize = 4096;

/// Fixed-capacity uniform sample of an unbounded stream (Algorithm R).
/// Deterministic: replacement slots come from a fixed-seed LCG, not a
/// global RNG, so metrics snapshots are reproducible in tests.
struct Reservoir {
    samples: Vec<f64>,
    /// Samples offered so far (not just kept).
    seen: u64,
    lcg: u64,
}

impl Reservoir {
    fn new() -> Self {
        Reservoir { samples: Vec::new(), seen: 0, lcg: 0x9e37_79b9_7f4a_7c15 }
    }

    fn next_below(&mut self, bound: u64) -> u64 {
        // MMIX LCG; the high bits are well mixed
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (self.lcg >> 16) % bound.max(1)
    }

    fn offer(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < RESERVOIR_CAP {
            self.samples.push(x);
            return;
        }
        let j = self.next_below(self.seen);
        if (j as usize) < RESERVOIR_CAP {
            self.samples[j as usize] = x;
        }
    }
}

#[derive(Default)]
struct Sum {
    total: f64,
    count: u64,
}

impl Sum {
    fn add(&mut self, x: f64) {
        self.total += x;
        self.count += 1;
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total / self.count as f64
        }
    }
}

/// Shared metrics sink updated by the worker thread.
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

struct Inner {
    latency: Sum,
    latency_samples: Reservoir,
    exec: Sum,
    batch_sum: u64,
    batch_count: u64,
    max_batch_seen: usize,
    completed: u64,
    errors: u64,
    /// Backend panics contained by the worker (each fails one batch).
    panics: u64,
    /// Typed load-shedding rejections, by [`Rejected`] class.
    rejected_queue_full: u64,
    rejected_deadline: u64,
    rejected_shutdown: u64,
    rejected_plan_unavailable: u64,
    rejected_unsupported_plan: u64,
    /// SIMD kernel ISA the serving backend dispatches to (set once by the
    /// worker at startup; `None` until a backend reports in).
    kernel_isa: Option<&'static str>,
    /// Auto-tuning report: `(chosen-config summary, startup sweep count)`
    /// when the backend's policy came from the execution autotuner.
    tuned: Option<(String, u64)>,
}

impl Inner {
    fn new() -> Self {
        Inner {
            latency: Sum::default(),
            latency_samples: Reservoir::new(),
            exec: Sum::default(),
            batch_sum: 0,
            batch_count: 0,
            max_batch_seen: 0,
            completed: 0,
            errors: 0,
            panics: 0,
            rejected_queue_full: 0,
            rejected_deadline: 0,
            rejected_shutdown: 0,
            rejected_plan_unavailable: 0,
            rejected_unsupported_plan: 0,
            kernel_isa: None,
            tuned: None,
        }
    }
}

/// Point-in-time metrics summary.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Requests answered with a typed [`Rejected`] (load shedding).
    pub rejected: u64,
    /// [`Rejected::QueueFull`] answers.
    pub rejected_queue_full: u64,
    /// [`Rejected::DeadlineExceeded`] answers.
    pub rejected_deadline: u64,
    /// [`Rejected::ShuttingDown`] answers.
    pub rejected_shutdown: u64,
    /// [`Rejected::PlanUnavailable`] answers.
    pub rejected_plan_unavailable: u64,
    /// [`Rejected::UnsupportedPlan`] answers (capability mismatch or
    /// `--max-error` budget violation — the route resolved fine).
    pub rejected_unsupported_plan: u64,
    /// Backend panics the worker contained (each failed one batch but
    /// kept the coordinator serving).
    pub panics_contained: u64,
    /// Mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Median latency (s) — exact up to [`RESERVOIR_CAP`] requests,
    /// reservoir-estimated beyond.
    pub p50_latency_s: f64,
    /// 99th-percentile latency (s) — same estimator as `p50_latency_s`.
    pub p99_latency_s: f64,
    /// Mean backend execution time per batch (s).
    pub mean_exec_s: f64,
    /// Mean live requests per executed batch.
    pub mean_batch: f64,
    /// Largest batch executed.
    pub max_batch_seen: usize,
    /// SIMD kernel ISA the backend dispatches to (`"unknown"` until the
    /// worker reports, `"n/a"` for non-native backends).
    pub kernel_isa: &'static str,
    /// Summary of the auto-tuned execution config (e.g.
    /// `pool/8t/tile32/mw2048/auto`), or `"off"` when the backend was not
    /// auto-tuned.
    pub tuned: String,
    /// Number of calibration candidates the startup sweep measured — 0
    /// when the config came from a cache or a preloaded `.fasttune`
    /// profile, and when tuning is off.
    pub tune_sweeps: u64,
}

impl ServeMetrics {
    /// Fresh sink.
    pub fn new() -> Self {
        ServeMetrics { inner: Mutex::new(Inner::new()) }
    }

    /// Record one successful request.
    pub fn record(&self, latency_s: f64, exec_s: f64, batch: usize) {
        let mut g = self.inner.lock().unwrap();
        g.latency.add(latency_s);
        g.latency_samples.offer(latency_s);
        g.exec.add(exec_s);
        g.batch_sum += batch as u64;
        g.batch_count += 1;
        g.max_batch_seen = g.max_batch_seen.max(batch);
        g.completed += 1;
    }

    /// Record one failed request.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record one request answered with a typed rejection.
    pub fn record_rejected(&self, r: &Rejected) {
        let mut g = self.inner.lock().unwrap();
        match r {
            Rejected::QueueFull { .. } => g.rejected_queue_full += 1,
            Rejected::DeadlineExceeded => g.rejected_deadline += 1,
            Rejected::ShuttingDown => g.rejected_shutdown += 1,
            Rejected::PlanUnavailable { .. } => g.rejected_plan_unavailable += 1,
            Rejected::UnsupportedPlan { .. } => g.rejected_unsupported_plan += 1,
        }
    }

    /// Record one contained backend panic (the affected batch failed but
    /// the worker kept serving).
    pub fn record_panic(&self) {
        self.inner.lock().unwrap().panics += 1;
    }

    /// Number of latency samples currently held for the percentile
    /// estimates — bounded by [`RESERVOIR_CAP`] no matter how many
    /// requests were recorded.
    pub fn samples_held(&self) -> usize {
        self.inner.lock().unwrap().latency_samples.samples.len()
    }

    /// Record the SIMD kernel ISA the backend dispatches to (reported by
    /// the serve worker once at startup).
    pub fn set_kernel_isa(&self, isa: &'static str) {
        self.inner.lock().unwrap().kernel_isa = Some(isa);
    }

    /// Record the auto-tuning report (chosen-config summary + startup
    /// sweep count), reported by the serve worker once at startup for
    /// auto-tuned backends.
    pub fn set_tuned(&self, summary: String, sweeps: u64) {
        self.inner.lock().unwrap().tuned = Some((summary, sweeps));
    }

    /// Snapshot the current statistics.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            completed: g.completed,
            errors: g.errors,
            rejected: g.rejected_queue_full
                + g.rejected_deadline
                + g.rejected_shutdown
                + g.rejected_plan_unavailable
                + g.rejected_unsupported_plan,
            rejected_queue_full: g.rejected_queue_full,
            rejected_deadline: g.rejected_deadline,
            rejected_shutdown: g.rejected_shutdown,
            rejected_plan_unavailable: g.rejected_plan_unavailable,
            rejected_unsupported_plan: g.rejected_unsupported_plan,
            panics_contained: g.panics,
            mean_latency_s: g.latency.mean(),
            p50_latency_s: crate::linalg::percentile(&g.latency_samples.samples, 50.0),
            p99_latency_s: crate::linalg::percentile(&g.latency_samples.samples, 99.0),
            mean_exec_s: g.exec.mean(),
            mean_batch: if g.batch_count == 0 {
                0.0
            } else {
                g.batch_sum as f64 / g.batch_count as f64
            },
            max_batch_seen: g.max_batch_seen,
            kernel_isa: g.kernel_isa.unwrap_or("unknown"),
            tuned: g.tuned.as_ref().map_or_else(|| "off".to_string(), |(s, _)| s.clone()),
            tune_sweeps: g.tuned.as_ref().map_or(0, |&(_, n)| n),
        }
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsSnapshot {
    /// One-line human summary.
    pub fn line(&self) -> String {
        format!(
            "completed={} errors={} p50={:.1}µs p99={:.1}µs mean_exec={:.1}µs mean_batch={:.2} max_batch={} kernel={} tuned={} sweeps={} rejected={} panics={}",
            self.completed,
            self.errors,
            self.p50_latency_s * 1e6,
            self.p99_latency_s * 1e6,
            self.mean_exec_s * 1e6,
            self.mean_batch,
            self.max_batch_seen,
            self.kernel_isa,
            self.tuned,
            self.tune_sweeps,
            self.rejected,
            self.panics_contained
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServeMetrics::new();
        m.record(0.001, 0.0005, 3);
        m.record(0.003, 0.0005, 5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_latency_s - 0.002).abs() < 1e-12);
        assert_eq!(s.max_batch_seen, 5);
        assert!((s.mean_batch - 4.0).abs() < 1e-12);
        assert_eq!(s.kernel_isa, "unknown", "no backend reported a kernel yet");
        assert_eq!(s.tuned, "off", "no backend reported auto-tuning yet");
        assert_eq!(s.tune_sweeps, 0);
        m.set_kernel_isa("avx2");
        assert_eq!(m.snapshot().kernel_isa, "avx2");
        assert!(m.snapshot().line().contains("kernel=avx2"));
        m.set_tuned("pool/4t/tile16/mw2048/auto".to_string(), 5);
        let s = m.snapshot();
        assert_eq!(s.tuned, "pool/4t/tile16/mw2048/auto");
        assert_eq!(s.tune_sweeps, 5);
        assert!(s.line().contains("tuned=pool/4t/tile16/mw2048/auto"));
        assert!(s.line().contains("sweeps=5"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.max_batch_seen, 0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.panics_contained, 0);
    }

    #[test]
    fn rejection_classes_are_counted() {
        let m = ServeMetrics::new();
        m.record_rejected(&Rejected::QueueFull { retry_after_ms: 5 });
        m.record_rejected(&Rejected::QueueFull { retry_after_ms: 7 });
        m.record_rejected(&Rejected::DeadlineExceeded);
        m.record_rejected(&Rejected::ShuttingDown);
        m.record_rejected(&Rejected::PlanUnavailable { reason: "x".into() });
        m.record_rejected(&Rejected::UnsupportedPlan { reason: "y".into() });
        m.record_panic();
        let s = m.snapshot();
        assert_eq!(s.rejected_queue_full, 2);
        assert_eq!(s.rejected_deadline, 1);
        assert_eq!(s.rejected_shutdown, 1);
        assert_eq!(s.rejected_plan_unavailable, 1);
        assert_eq!(s.rejected_unsupported_plan, 1);
        assert_eq!(s.rejected, 6);
        assert_eq!(s.panics_contained, 1);
        assert!(s.line().contains("rejected=6"));
        assert!(s.line().contains("panics=1"));
    }

    #[test]
    fn million_sample_run_stays_bounded_and_percentiles_hold() {
        // regression for the unbounded seed metrics: latencies/exec/batch
        // grew one entry per request forever. One million records must
        // leave the sink holding at most RESERVOIR_CAP samples while the
        // exact aggregates and the percentile estimates stay usable.
        let m = ServeMetrics::new();
        let total = 1_000_000u64;
        for k in 0..total {
            // latencies sweep 0..1 ms uniformly (deterministic order)
            let latency = (k % 1000) as f64 * 1e-6;
            m.record(latency, 1e-6, (k % 8 + 1) as usize);
        }
        assert!(m.samples_held() <= RESERVOIR_CAP, "reservoir overflowed: {}", m.samples_held());
        let s = m.snapshot();
        assert_eq!(s.completed, total);
        // exact aggregates are unaffected by the sampling
        assert!((s.mean_latency_s - 0.4995e-3).abs() < 1e-9, "{}", s.mean_latency_s);
        assert_eq!(s.max_batch_seen, 8);
        assert!((s.mean_batch - 4.5).abs() < 1e-9);
        // reservoir estimates: p50 ≈ 0.5 ms, p99 ≈ 0.99 ms (loose bands —
        // the reservoir is a deterministic-LCG uniform sample)
        assert!(
            (0.40e-3..=0.60e-3).contains(&s.p50_latency_s),
            "p50 estimate off: {}",
            s.p50_latency_s
        );
        assert!(
            (0.90e-3..=1.00e-3).contains(&s.p99_latency_s),
            "p99 estimate off: {}",
            s.p99_latency_s
        );
    }

    #[test]
    fn small_counts_keep_exact_percentiles() {
        // below RESERVOIR_CAP the reservoir holds every sample, so the
        // percentiles must equal the exact ones
        let m = ServeMetrics::new();
        for k in 0..100 {
            m.record(k as f64, 0.0, 1);
        }
        let xs: Vec<f64> = (0..100).map(|k| k as f64).collect();
        let s = m.snapshot();
        assert_eq!(s.p50_latency_s, crate::linalg::percentile(&xs, 50.0));
        assert_eq!(s.p99_latency_s, crate::linalg::percentile(&xs, 99.0));
    }
}
