//! Serving metrics: latency samples, batch occupancy, error counts.

use std::sync::Mutex;

/// Shared metrics sink updated by the worker thread.
pub struct ServeMetrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latencies: Vec<f64>,
    exec_times: Vec<f64>,
    batch_sizes: Vec<usize>,
    completed: u64,
    errors: u64,
    /// SIMD kernel ISA the serving backend dispatches to (set once by the
    /// worker at startup; `None` until a backend reports in).
    kernel_isa: Option<&'static str>,
    /// Auto-tuning report: `(chosen-config summary, startup sweep count)`
    /// when the backend's policy came from the execution autotuner.
    tuned: Option<(String, u64)>,
}

/// Point-in-time metrics summary.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Requests answered successfully.
    pub completed: u64,
    /// Requests answered with an error.
    pub errors: u64,
    /// Mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Median latency (s).
    pub p50_latency_s: f64,
    /// 99th-percentile latency (s).
    pub p99_latency_s: f64,
    /// Mean backend execution time per batch (s).
    pub mean_exec_s: f64,
    /// Mean live requests per executed batch.
    pub mean_batch: f64,
    /// Largest batch executed.
    pub max_batch_seen: usize,
    /// SIMD kernel ISA the backend dispatches to (`"unknown"` until the
    /// worker reports, `"n/a"` for non-native backends).
    pub kernel_isa: &'static str,
    /// Summary of the auto-tuned execution config (e.g.
    /// `pool/8t/tile32/mw2048/auto`), or `"off"` when the backend was not
    /// auto-tuned.
    pub tuned: String,
    /// Number of calibration candidates the startup sweep measured — 0
    /// when the config came from a cache or a preloaded `.fasttune`
    /// profile, and when tuning is off.
    pub tune_sweeps: u64,
}

impl ServeMetrics {
    /// Fresh sink.
    pub fn new() -> Self {
        ServeMetrics { inner: Mutex::new(Inner::default()) }
    }

    /// Record one successful request.
    pub fn record(&self, latency_s: f64, exec_s: f64, batch: usize) {
        let mut g = self.inner.lock().unwrap();
        g.latencies.push(latency_s);
        g.exec_times.push(exec_s);
        g.batch_sizes.push(batch);
        g.completed += 1;
    }

    /// Record one failed request.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record the SIMD kernel ISA the backend dispatches to (reported by
    /// the serve worker once at startup).
    pub fn set_kernel_isa(&self, isa: &'static str) {
        self.inner.lock().unwrap().kernel_isa = Some(isa);
    }

    /// Record the auto-tuning report (chosen-config summary + startup
    /// sweep count), reported by the serve worker once at startup for
    /// auto-tuned backends.
    pub fn set_tuned(&self, summary: String, sweeps: u64) {
        self.inner.lock().unwrap().tuned = Some((summary, sweeps));
    }

    /// Snapshot the current statistics.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        MetricsSnapshot {
            completed: g.completed,
            errors: g.errors,
            mean_latency_s: mean(&g.latencies),
            p50_latency_s: crate::linalg::percentile(&g.latencies, 50.0),
            p99_latency_s: crate::linalg::percentile(&g.latencies, 99.0),
            mean_exec_s: mean(&g.exec_times),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
            },
            max_batch_seen: g.batch_sizes.iter().copied().max().unwrap_or(0),
            kernel_isa: g.kernel_isa.unwrap_or("unknown"),
            tuned: g.tuned.as_ref().map_or_else(|| "off".to_string(), |(s, _)| s.clone()),
            tune_sweeps: g.tuned.as_ref().map_or(0, |&(_, n)| n),
        }
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsSnapshot {
    /// One-line human summary.
    pub fn line(&self) -> String {
        format!(
            "completed={} errors={} p50={:.1}µs p99={:.1}µs mean_exec={:.1}µs mean_batch={:.2} max_batch={} kernel={} tuned={} sweeps={}",
            self.completed,
            self.errors,
            self.p50_latency_s * 1e6,
            self.p99_latency_s * 1e6,
            self.mean_exec_s * 1e6,
            self.mean_batch,
            self.max_batch_seen,
            self.kernel_isa,
            self.tuned,
            self.tune_sweeps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServeMetrics::new();
        m.record(0.001, 0.0005, 3);
        m.record(0.003, 0.0005, 5);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.errors, 1);
        assert!((s.mean_latency_s - 0.002).abs() < 1e-12);
        assert_eq!(s.max_batch_seen, 5);
        assert!((s.mean_batch - 4.0).abs() < 1e-12);
        assert_eq!(s.kernel_isa, "unknown", "no backend reported a kernel yet");
        assert_eq!(s.tuned, "off", "no backend reported auto-tuning yet");
        assert_eq!(s.tune_sweeps, 0);
        m.set_kernel_isa("avx2");
        assert_eq!(m.snapshot().kernel_isa, "avx2");
        assert!(m.snapshot().line().contains("kernel=avx2"));
        m.set_tuned("pool/4t/tile16/mw2048/auto".to_string(), 5);
        let s = m.snapshot();
        assert_eq!(s.tuned, "pool/4t/tile16/mw2048/auto");
        assert_eq!(s.tune_sweeps, 5);
        assert!(s.line().contains("tuned=pool/4t/tile16/mw2048/auto"));
        assert!(s.line().contains("sweeps=5"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = ServeMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.max_batch_seen, 0);
    }
}
