//! Background warm-start refactorization for drifted graphs.
//!
//! When the served graph drifts (edge added/removed/reweighted), the
//! resident plan's chain is still a legal initialization for the new
//! Laplacian — the paper's coordinate minimizers accept any starting
//! point. This module re-polishes the donor chain against the drifted
//! matrix ([`SymFactorizer::run_with_chain`] /
//! [`SymFactorizer::run_to_budget_warm`]), re-measures the error
//! certificate **against the drifted matrix** (a warm-started plan must
//! never inherit the donor's Lemma-1 spectrum or certificate), and
//! atomically [`PlanRegistry::install_default`]s the new `Arc<Plan>`
//! while in-flight batches drain on the old one.
//!
//! The swap is the registry's existing atomic primitive, so the
//! zero-downtime property comes for free: requests resolve their plan at
//! submit time and own the `Arc`, so anything submitted before the swap
//! completes bitwise-identically on the old plan.
//!
//! [`RefactorWorker`] runs these jobs on one dedicated background
//! thread: wire `refactor` requests and `--watch-graph` file events
//! enqueue, the server keeps serving, and jobs are serialized so two
//! drift events can never race their `install_default` ordering.

use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::bail;

use crate::factor::{BudgetRunStats, FactorExec, SymFactorizer, SymOptions};
use crate::linalg::Mat;
use crate::plan::Plan;
use crate::transforms::ErrorCertificate;

use super::registry::PlanRegistry;

/// Tunables for one warm-start refactorization.
#[derive(Clone, Debug)]
pub struct RefactorOptions {
    /// Error budget: grow `g` (through the `run_to_budget` machinery)
    /// until the re-measured certificate meets this. `None` re-polishes
    /// at the donor length without growing.
    pub budget: Option<f64>,
    /// Growth cap on `g` when a budget is set. `None` → 4× the donor
    /// length.
    pub max_g: Option<usize>,
    /// Swap refusal threshold (`serve --max-error`): the refactored
    /// plan is not installed as default unless its certificate meets
    /// this budget.
    pub max_error: Option<f64>,
    /// Sweep cap for each polish round.
    pub max_sweeps: usize,
    /// Deterministic parallel execution config for the factorizer.
    pub exec: FactorExec,
}

impl Default for RefactorOptions {
    fn default() -> Self {
        RefactorOptions {
            budget: None,
            max_g: None,
            max_error: None,
            max_sweeps: SymOptions::default().max_sweeps,
            exec: FactorExec::default(),
        }
    }
}

/// A refactored plan, before any swap decision.
#[derive(Clone, Debug)]
pub struct RefactorResult {
    /// The warm-started plan: donor chain re-polished against the
    /// drifted matrix, Lemma-1 spectrum and certificate re-measured
    /// against it.
    pub plan: Arc<Plan>,
    /// Certificate measured against the drifted matrix.
    pub certificate: ErrorCertificate,
    /// Cumulative warm-start work (sweeps, growth rounds, appended
    /// factors beyond the donor chain).
    pub stats: BudgetRunStats,
    /// Final chain length.
    pub g: usize,
}

/// What a refactor-and-swap attempt did.
#[derive(Clone, Debug)]
pub struct RefactorOutcome {
    /// Content checksum of the donor plan.
    pub old_checksum: u64,
    /// Content checksum of the refactored plan.
    pub new_checksum: u64,
    /// Re-measured relative error against the drifted matrix.
    pub rel_err: f64,
    /// Final chain length.
    pub g: usize,
    /// Polish sweeps summed over every growth round.
    pub sweeps: usize,
    /// `g`-doubling rounds beyond the first warm replay.
    pub growth_rounds: usize,
    /// Factors appended beyond the donor chain.
    pub factors_added: usize,
    /// Whether the registry default was swapped to the new plan.
    pub swapped: bool,
    /// Why the swap was refused (`swapped == false` and the resident
    /// plan stays).
    pub refused: Option<String>,
}

/// Warm-start the donor plan's chain against the drifted matrix `s` and
/// build a freshly certified plan. The spectrum is the Lemma-1 diagonal
/// `diag(ŪᵀS′Ū)` recomputed against `s` and the certificate is measured
/// against `s` — nothing is inherited from the donor artifact.
pub fn refactor_plan(
    donor: &Plan,
    s: &Mat,
    opts: &RefactorOptions,
) -> crate::Result<RefactorResult> {
    let Some(chain) = donor.as_gchain() else {
        bail!(
            "refactor needs a G-chain (symmetric) donor plan; plan {:016x} holds a T-chain",
            donor.content_checksum()
        );
    };
    if s.rows() != chain.n {
        bail!(
            "drifted matrix is {}×{} but donor plan {:016x} is for n={}",
            s.rows(),
            s.cols(),
            donor.content_checksum(),
            chain.n
        );
    }
    if s.symmetry_defect() >= 1e-8 * (1.0 + s.max_abs()) {
        bail!(
            "drifted matrix is not symmetric (defect {:.3e}) — a G-chain warm start needs a \
             symmetric matrix",
            s.symmetry_defect()
        );
    }
    let sym_opts =
        SymOptions { max_sweeps: opts.max_sweeps, exec: opts.exec, ..Default::default() };
    let (f, cert, stats) = match opts.budget {
        Some(budget) => {
            let g_max = opts.max_g.unwrap_or_else(|| chain.len().saturating_mul(4).max(1));
            SymFactorizer::run_to_budget_warm(s, chain.clone(), budget, g_max, sym_opts)
        }
        None => {
            let g = chain.len().max(1);
            let donor_len = chain.len();
            let f = SymFactorizer::new(s, g, sym_opts).run_with_chain(chain.clone());
            let cert = f.certificate(s);
            let stats = BudgetRunStats {
                growth_rounds: 0,
                total_sweeps: f.sweeps_run,
                factors_added: f.chain.len().saturating_sub(donor_len),
            };
            (f, cert, stats)
        }
    };
    let g = f.chain.len();
    let plan = Plan::from(&f.chain)
        .spectrum(f.spectrum.clone())
        .certificate(cert.clone())
        .build();
    Ok(RefactorResult { plan, certificate: cert, stats, g })
}

/// [`refactor_plan`] + swap decision: install the refactored plan as
/// the registry default unless its certificate misses
/// [`RefactorOptions::max_error`] (in which case the resident plan
/// stays and the outcome says why). The swap is atomic; in-flight
/// batches drain on the old plan.
pub fn refactor_and_swap(
    registry: &PlanRegistry,
    donor: &Plan,
    s: &Mat,
    opts: &RefactorOptions,
) -> crate::Result<RefactorOutcome> {
    let r = refactor_plan(donor, s, opts)?;
    let mut outcome = RefactorOutcome {
        old_checksum: donor.content_checksum(),
        new_checksum: r.plan.content_checksum(),
        rel_err: r.certificate.rel_err,
        g: r.g,
        sweeps: r.stats.total_sweeps,
        growth_rounds: r.stats.growth_rounds,
        factors_added: r.stats.factors_added,
        swapped: false,
        refused: None,
    };
    if let Some(eps) = opts.max_error {
        if !r.certificate.meets(eps) {
            outcome.refused = Some(format!(
                "refactored certificate rel_err {:.3e} exceeds --max-error {eps:.3e} — keeping \
                 the resident plan",
                r.certificate.rel_err
            ));
            return Ok(outcome);
        }
    }
    registry.install_default(r.plan);
    outcome.swapped = true;
    Ok(outcome)
}

/// One queued refactorization.
pub struct RefactorJob {
    /// The drifted (symmetric) matrix to warm-start against.
    pub matrix: Mat,
    /// Donor plan checksum; `None` warm-starts from the registry
    /// default at the moment the job runs.
    pub from: Option<u64>,
    /// Per-job tunables (budget, growth cap, swap threshold).
    pub opts: RefactorOptions,
    /// Reply channel for synchronous callers; `None` logs to stderr.
    pub reply: Option<Sender<crate::Result<RefactorOutcome>>>,
}

/// Dedicated background thread running [`RefactorJob`]s in order.
pub struct RefactorWorker {
    tx: Option<Sender<RefactorJob>>,
    handle: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for RefactorWorker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("RefactorWorker")
    }
}

impl RefactorWorker {
    /// Spawn the worker over the registry it will swap plans into.
    pub fn start(registry: Arc<PlanRegistry>) -> RefactorWorker {
        let (tx, rx) = mpsc::channel::<RefactorJob>();
        let handle = std::thread::Builder::new()
            .name("fastes-refactor".into())
            .spawn(move || {
                for job in rx {
                    let RefactorJob { matrix, from, opts, reply } = job;
                    let res = (|| {
                        let donor = match from {
                            Some(key) => registry.get(key)?,
                            None => registry.default_plan().ok_or_else(|| {
                                anyhow::anyhow!("no default plan to warm-start from")
                            })?,
                        };
                        refactor_and_swap(&registry, &donor, &matrix, &opts)
                    })();
                    match reply {
                        Some(tx) => {
                            let _ = tx.send(res);
                        }
                        None => match res {
                            Ok(o) if o.swapped => eprintln!(
                                "refactor: swapped default {:016x} → {:016x} \
                                 (rel_err {:.3e}, g {}, {} sweeps)",
                                o.old_checksum, o.new_checksum, o.rel_err, o.g, o.sweeps
                            ),
                            Ok(o) => eprintln!(
                                "refactor: swap refused: {}",
                                o.refused.as_deref().unwrap_or("(no reason)")
                            ),
                            Err(e) => eprintln!("refactor failed: {e:#}"),
                        },
                    }
                }
            })
            .expect("spawn refactor worker");
        RefactorWorker { tx: Some(tx), handle: Some(handle) }
    }

    /// Enqueue a job; `false` if the worker thread is gone.
    pub fn submit(&self, job: RefactorJob) -> bool {
        match &self.tx {
            Some(tx) => tx.send(job).is_ok(),
            None => false,
        }
    }
}

impl Drop for RefactorWorker {
    fn drop(&mut self) {
        // closing the channel ends the worker loop; join so queued
        // swaps complete before shutdown returns
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
