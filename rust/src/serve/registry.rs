//! Multi-plan registry: many graphs' operators resident in one process.
//!
//! A [`PlanRegistry`] keys `Arc<Plan>`s by their **content checksum**
//! (`Plan::content_checksum` — the FNV-1a-64 of the canonical `.fastplan`
//! bytes), holds at most `capacity` of them under LRU eviction, and loads
//! `.fastplan` artifacts on demand from its search directories (file name
//! `{checksum:016x}.fastplan`). A corrupt, truncated, or missing artifact
//! is a **per-request error** — the registry stays up and every other
//! plan keeps serving.
//!
//! Hot swap: [`install_default`](PlanRegistry::install_default) /
//! [`set_default`](PlanRegistry::set_default) atomically repoint the
//! *default route* (the plan used by requests that don't name a
//! checksum). In-flight batches hold their own `Arc<Plan>` clone,
//! resolved at submit time, so they drain on the old plan while every
//! request submitted after the swap serves on the new one; the old plan's
//! memory is freed when the last in-flight reference drops. Eviction has
//! the same property — it only drops the registry's reference.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::Context;

use super::faults::{self, FaultAction};
use crate::plan::Plan;
use crate::transforms::ErrorCertificate;

struct Entry {
    plan: Arc<Plan>,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    plans: HashMap<u64, Entry>,
    default_key: Option<u64>,
    tick: u64,
    hits: u64,
    misses: u64,
    loads: u64,
    load_errors: u64,
    evictions: u64,
}

/// Point-in-time registry counters (reported by the serve metrics
/// endpoint).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegistryStats {
    /// Plans currently resident.
    pub resident: usize,
    /// LRU capacity.
    pub capacity: usize,
    /// Lookups answered from a resident plan.
    pub hits: u64,
    /// Lookups that had to go to disk (successful or not).
    pub misses: u64,
    /// Artifacts loaded from disk.
    pub loads: u64,
    /// Artifact loads that failed (missing/corrupt/truncated files).
    pub load_errors: u64,
    /// Plans evicted by the LRU.
    pub evictions: u64,
    /// Content checksum of the current default plan.
    pub default_checksum: Option<u64>,
}

/// One resident plan's routing identity and accuracy, as surfaced by the
/// serve `metrics` wire reply: routing key, dimensions, and the measured
/// `.fastplan` error certificate when the artifact carries one (v3).
#[derive(Clone, Debug)]
pub struct ResidentPlanInfo {
    /// Content checksum (the routing key).
    pub checksum: u64,
    /// Signal dimension.
    pub n: usize,
    /// Compiled stage count `g`.
    pub g: usize,
    /// Whether this plan backs the default route.
    pub is_default: bool,
    /// The artifact's measured error certificate, if it has one.
    pub certificate: Option<ErrorCertificate>,
}

/// Capacity-bounded LRU of `Arc<Plan>`s keyed by content checksum (see
/// the module docs).
pub struct PlanRegistry {
    inner: Mutex<Inner>,
    capacity: usize,
    search_dirs: Vec<PathBuf>,
}

impl PlanRegistry {
    /// Registry holding at most `capacity` plans (minimum 1), with no
    /// on-demand loading.
    pub fn new(capacity: usize) -> Self {
        Self::with_search_dirs(capacity, Vec::new())
    }

    /// Registry that also loads `{checksum:016x}.fastplan` artifacts on
    /// demand from `search_dirs`, first match wins.
    pub fn with_search_dirs(capacity: usize, search_dirs: Vec<PathBuf>) -> Self {
        PlanRegistry { inner: Mutex::new(Inner::default()), capacity: capacity.max(1), search_dirs }
    }

    /// Insert a plan (keyed by its content checksum) and return the key.
    /// Re-inserting an identical plan just refreshes its LRU slot.
    pub fn insert(&self, plan: Arc<Plan>) -> u64 {
        let key = plan.content_checksum();
        let mut g = self.inner.lock().unwrap();
        Self::touch(&mut g, key, plan);
        self.evict_excess(&mut g);
        key
    }

    /// Insert a plan and atomically make it the default route. Returns
    /// the key. This is the hot-swap primitive: requests submitted after
    /// this call resolve the new plan; batches already in flight hold
    /// their `Arc` to the old one and drain unaffected.
    pub fn install_default(&self, plan: Arc<Plan>) -> u64 {
        let key = plan.content_checksum();
        let mut g = self.inner.lock().unwrap();
        Self::touch(&mut g, key, plan);
        g.default_key = Some(key);
        self.evict_excess(&mut g);
        key
    }

    /// Repoint the default route at an already-known (or loadable) plan.
    pub fn set_default(&self, key: u64) -> crate::Result<Arc<Plan>> {
        let plan = self.get(key)?;
        self.inner.lock().unwrap().default_key = Some(key);
        Ok(plan)
    }

    /// The current default plan (`None` until one is installed).
    pub fn default_plan(&self) -> Option<Arc<Plan>> {
        let mut g = self.inner.lock().unwrap();
        let key = g.default_key?;
        g.tick += 1;
        let tick = g.tick;
        let e = g.plans.get_mut(&key)?;
        e.last_used = tick;
        Some(Arc::clone(&e.plan))
    }

    /// Look up a plan by content checksum, loading it from the search
    /// directories on a miss. Every failure (unknown key, unreadable or
    /// corrupt artifact, checksum mismatch) is a per-request `Err`.
    pub fn get(&self, key: u64) -> crate::Result<Arc<Plan>> {
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.plans.get_mut(&key) {
                e.last_used = tick;
                let plan = Arc::clone(&e.plan);
                g.hits += 1;
                return Ok(plan);
            }
            g.misses += 1;
        }
        // load outside the map lookup above; the lock is re-taken to
        // publish (a racing double-load of the same artifact is benign —
        // both decode to the identical plan)
        match self.load_from_disk(key) {
            Ok(plan) => {
                let mut g = self.inner.lock().unwrap();
                g.loads += 1;
                Self::touch(&mut g, key, Arc::clone(&plan));
                self.evict_excess(&mut g);
                Ok(plan)
            }
            Err(e) => {
                self.inner.lock().unwrap().load_errors += 1;
                Err(e)
            }
        }
    }

    /// Snapshot of every resident plan's identity and error certificate,
    /// sorted by checksum (deterministic for the metrics reply). Does not
    /// touch LRU state — observation must not change eviction order.
    pub fn resident_plans(&self) -> Vec<ResidentPlanInfo> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<ResidentPlanInfo> = g
            .plans
            .iter()
            .map(|(&key, e)| ResidentPlanInfo {
                checksum: key,
                n: e.plan.n(),
                g: e.plan.len(),
                is_default: Some(key) == g.default_key,
                certificate: e.plan.certificate().cloned(),
            })
            .collect();
        out.sort_by_key(|p| p.checksum);
        out
    }

    /// Current counters.
    pub fn stats(&self) -> RegistryStats {
        let g = self.inner.lock().unwrap();
        RegistryStats {
            resident: g.plans.len(),
            capacity: self.capacity,
            hits: g.hits,
            misses: g.misses,
            loads: g.loads,
            load_errors: g.load_errors,
            evictions: g.evictions,
            default_checksum: g.default_key,
        }
    }

    fn touch(g: &mut Inner, key: u64, plan: Arc<Plan>) {
        g.tick += 1;
        let tick = g.tick;
        g.plans.entry(key).or_insert(Entry { plan, last_used: 0 }).last_used = tick;
    }

    fn evict_excess(&self, g: &mut Inner) {
        while g.plans.len() > self.capacity {
            // least-recently-used non-default entry; the default is
            // pinned (it backs every un-routed request)
            let victim = g
                .plans
                .iter()
                .filter(|(k, _)| Some(**k) != g.default_key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    g.plans.remove(&k);
                    g.evictions += 1;
                }
                None => return, // only the pinned default remains
            }
        }
    }

    fn load_from_disk(&self, key: u64) -> crate::Result<Arc<Plan>> {
        let file = format!("{key:016x}.fastplan");
        for dir in &self.search_dirs {
            let path = dir.join(&file);
            if !path.exists() {
                continue;
            }
            let mut bytes = std::fs::read(&path)
                .with_context(|| format!("reading plan artifact {}", path.display()))?;
            if let Some(FaultAction::Truncate(keep)) = faults::fire("registry.load") {
                bytes.truncate(keep.min(bytes.len()));
            }
            let plan = Plan::from_bytes(&bytes)
                .with_context(|| format!("loading plan artifact {}", path.display()))?;
            if plan.content_checksum() != key {
                anyhow::bail!(
                    "plan artifact {} decodes to checksum {:016x}, expected {key:016x}",
                    path.display(),
                    plan.content_checksum()
                );
            }
            return Ok(plan);
        }
        anyhow::bail!(
            "plan {key:016x} is not resident and no search directory holds {file} \
             (searched {} directories)",
            self.search_dirs.len()
        )
    }
}

impl std::fmt::Debug for PlanRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "PlanRegistry(resident={}/{}, hits={}, misses={}, evictions={})",
            s.resident, s.capacity, s.hits, s.misses, s.evictions
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::Plan;
    use crate::transforms::{GChain, GKind, GTransform};

    fn plan_with(n: usize, g: usize, seed: u64) -> Arc<Plan> {
        let mut rng = crate::linalg::Rng64::new(seed);
        let mut ch = GChain::identity(n);
        for _ in 0..g {
            let i = rng.below(n - 1);
            let j = i + 1 + rng.below(n - 1 - i);
            let th = rng.uniform_in(0.0, std::f64::consts::TAU);
            ch.transforms.push(GTransform::new(i, j, th.cos(), th.sin(), GKind::Rotation));
        }
        Plan::from(ch).build()
    }

    #[test]
    fn insert_get_and_default_routing() {
        let reg = PlanRegistry::new(4);
        let a = plan_with(8, 10, 1);
        let b = plan_with(8, 10, 2);
        let ka = reg.install_default(Arc::clone(&a));
        let kb = reg.insert(Arc::clone(&b));
        assert_ne!(ka, kb, "distinct plans must key differently");
        assert!(Arc::ptr_eq(&reg.get(ka).unwrap(), &a));
        assert!(Arc::ptr_eq(&reg.get(kb).unwrap(), &b));
        assert!(Arc::ptr_eq(&reg.default_plan().unwrap(), &a));
        assert_eq!(reg.stats().default_checksum, Some(ka));
        // hot swap: default moves to b, a stays resident
        reg.set_default(kb).unwrap();
        assert!(Arc::ptr_eq(&reg.default_plan().unwrap(), &b));
        assert!(reg.get(ka).is_ok());
    }

    #[test]
    fn unknown_key_is_a_per_request_error() {
        let reg = PlanRegistry::new(2);
        let e = format!("{:#}", reg.get(0xdead_beef).unwrap_err());
        assert!(e.contains("not resident"), "{e}");
        assert_eq!(reg.stats().load_errors, 1);
        assert_eq!(reg.stats().misses, 1);
    }

    #[test]
    fn lru_evicts_oldest_but_pins_default() {
        let reg = PlanRegistry::new(2);
        let d = reg.install_default(plan_with(8, 6, 10));
        let k1 = reg.insert(plan_with(8, 6, 11));
        // touch the default so k1 is the LRU entry, then overflow
        assert!(reg.default_plan().is_some());
        let k2 = reg.insert(plan_with(8, 6, 12));
        let s = reg.stats();
        assert_eq!(s.resident, 2);
        assert_eq!(s.evictions, 1);
        assert!(reg.get(d).is_ok(), "default must never be evicted");
        assert!(reg.get(k2).is_ok(), "most recent insert survives");
        assert!(reg.get(k1).is_err(), "LRU entry was evicted");
    }

    #[test]
    fn resident_plans_surface_certificates_without_touching_lru() {
        let reg = PlanRegistry::new(4);
        let plain = plan_with(6, 8, 30);
        let kp = reg.install_default(Arc::clone(&plain));

        // build a certified plan (exact factorization → rel_err == 0)
        let mut rng = crate::linalg::Rng64::new(31);
        let ch = crate::cli::figures::random_gplan(6, 12, &mut rng);
        let spec: Vec<f64> = (0..6).map(|i| i as f64 + 0.5).collect();
        let s = ch.reconstruct(&spec);
        let cert = crate::transforms::certify_g(&ch, &s, &spec, &[0.25]);
        let certified = Plan::from(&ch).spectrum(spec).certificate(cert.clone()).build();
        let kc = reg.insert(Arc::clone(&certified));

        let infos = reg.resident_plans();
        assert_eq!(infos.len(), 2);
        assert!(infos.windows(2).all(|w| w[0].checksum < w[1].checksum), "sorted");
        let p = infos.iter().find(|i| i.checksum == kp).unwrap();
        assert!(p.is_default && p.certificate.is_none());
        assert_eq!((p.n, p.g), (6, 8));
        let c = infos.iter().find(|i| i.checksum == kc).unwrap();
        assert!(!c.is_default);
        let got = c.certificate.as_ref().unwrap();
        assert_eq!(got.rel_err.to_bits(), cert.rel_err.to_bits());
        assert_eq!(got.g, 12);
        // observation is not a use: LRU counters untouched
        assert_eq!(reg.stats().hits, 0);
    }

    #[test]
    fn loads_artifacts_on_demand_and_rejects_mismatched_names() {
        let dir = std::env::temp_dir().join(format!("fastes-registry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let plan = plan_with(10, 14, 20);
        let key = plan.content_checksum();
        std::fs::write(dir.join(format!("{key:016x}.fastplan")), plan.to_bytes()).unwrap();
        // a file whose name lies about its content must be rejected
        let other = plan_with(10, 14, 21);
        let lie = key ^ 1;
        std::fs::write(dir.join(format!("{lie:016x}.fastplan")), other.to_bytes()).unwrap();

        let reg = PlanRegistry::with_search_dirs(4, vec![dir.clone()]);
        let got = reg.get(key).unwrap();
        assert_eq!(got.content_checksum(), key);
        assert_eq!(reg.stats().loads, 1);
        // second hit is resident
        reg.get(key).unwrap();
        assert_eq!(reg.stats().hits, 1);

        let e = format!("{:#}", reg.get(lie).unwrap_err());
        assert!(e.contains("expected"), "{e}");

        let _ = std::fs::remove_dir_all(&dir);
    }
}
