//! Minimal benchmarking harness (criterion is unavailable in this
//! environment's offline crate snapshot — see Cargo.toml).
//!
//! Provides warmed-up, repeated timing with mean / std / min statistics
//! and ns-per-iteration reporting. The `cargo bench` targets are plain
//! `harness = false` binaries built on this module.

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Mean wall time per iteration, seconds.
    pub mean_s: f64,
    /// Standard deviation across measurement batches, seconds.
    pub std_s: f64,
    /// Minimum batch mean, seconds.
    pub min_s: f64,
    /// Number of iterations per batch.
    pub iters_per_batch: usize,
}

impl BenchResult {
    /// Human-readable one-line summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:<10} (min {})",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark `f`, auto-calibrating the batch size so one batch takes
/// roughly `target_batch_s`, then running `batches` measured batches after
/// one warm-up batch. A `black_box`-style sink prevents the optimizer from
/// deleting the work: `f` should return a value that depends on its
/// computation.
pub fn bench<R>(name: &str, batches: usize, target_batch_s: f64, mut f: impl FnMut() -> R) -> BenchResult {
    // calibrate
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            sink(f());
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt >= target_batch_s || iters >= 1 << 24 {
            break;
        }
        let grow = if dt <= 1e-9 { 16.0 } else { (target_batch_s / dt).min(16.0).max(2.0) };
        iters = ((iters as f64) * grow).ceil() as usize;
    }
    // warm-up
    for _ in 0..iters {
        sink(f());
    }
    // measure
    let mut means = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..iters {
            sink(f());
        }
        means.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    let mean = means.iter().sum::<f64>() / means.len() as f64;
    let var = means.iter().map(|m| (m - mean) * (m - mean)).sum::<f64>() / means.len() as f64;
    let min = means.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
        iters_per_batch: iters,
    }
}

/// Opaque value sink (stable-rust black box).
#[inline]
pub fn sink<T>(value: T) -> T {
    std::hint::black_box(value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 3, 0.005, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
        assert!(r.iters_per_batch >= 1);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5).contains(" s"));
    }
}
