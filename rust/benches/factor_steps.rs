//! Factorization-engine benches: per-phase cost of Algorithm 1 —
//! Theorem-1 init throughput (factors/s), polish sweep cost, and the
//! general-case (T) init cost; plus thread scaling of the deterministic
//! parallel factorizer and the symmetric eigensolver substrate.
//!
//! Run with: `cargo bench --bench factor_steps`

use fastes::bench_util::bench;
use fastes::factor::{FactorExec, GeneralFactorizer, GeneralOptions, SymFactorizer, SymOptions};
use fastes::graphs;
use fastes::linalg::{eigh, Mat, Rng64};
use fastes::plan::{Direction, ExecPolicy, FastOperator};
use fastes::transforms::SignalBlock;

fn main() {
    println!("# factor_steps — Algorithm 1 phase costs");
    for n in [64usize, 128, 256] {
        let mut rng = Rng64::new(5);
        let graph = graphs::community(n, &mut rng);
        let l = graph.laplacian();
        let g = 2 * n * (n as f64).log2() as usize;

        let t_init = bench(&format!("sym init+0 sweeps n={n} g={g}"), 3, 0.2, || {
            let f = SymFactorizer::new(
                &l,
                g,
                SymOptions { max_sweeps: 0, ..Default::default() },
            )
            .run();
            f.init_objective
        });
        println!("{}  ({:.0} factors/s)", t_init.line(), g as f64 / t_init.min_s);

        let t_full = bench(&format!("sym init+2 sweeps n={n} g={g}"), 3, 0.2, || {
            let f = SymFactorizer::new(
                &l,
                g,
                SymOptions { max_sweeps: 2, eps: 0.0, ..Default::default() },
            )
            .run();
            f.objective()
        });
        println!("{}", t_full.line());
    }
    // T-transform init (the O(n²)-per-factor path)
    for n in [32usize, 64] {
        let mut rng = Rng64::new(6);
        let c = Mat::randn(n, n, &mut rng);
        let m = n * (n as f64).log2() as usize;
        let t = bench(&format!("gen init+1 sweep n={n} m={m}"), 3, 0.3, || {
            let f = GeneralFactorizer::new(
                &c,
                m,
                GeneralOptions { max_sweeps: 1, eps: 0.0, ..Default::default() },
            )
            .run();
            f.objective()
        });
        println!("{}  ({:.0} factors/s)", t.line(), m as f64 / t.min_s);
    }
    // thread scaling: the deterministic parallel factorizer vs serial.
    // min_work 0 forces the pool paths even at bench sizes; the chain is
    // bitwise-identical across rows, so only the timing moves.
    for n in [128usize, 256] {
        let mut rng = Rng64::new(9);
        let graph = graphs::community(n, &mut rng);
        let l = graph.laplacian();
        let g = 2 * n * (n as f64).log2() as usize;
        for threads in [1usize, 2, 4, 8] {
            let exec = if threads == 1 {
                FactorExec::serial()
            } else {
                FactorExec { threads, min_work: 0 }
            };
            let opts = SymOptions { max_sweeps: 0, exec, ..Default::default() };
            let t = bench(&format!("sym init n={n} g={g} threads={threads}"), 3, 0.2, || {
                SymFactorizer::new(&l, g, opts.clone()).run().init_objective
            });
            println!("{}  ({:.0} factors/s)", t.line(), g as f64 / t.min_s);
        }
    }
    let n = 64usize;
    let mut rng = Rng64::new(10);
    let c = Mat::randn(n, n, &mut rng);
    let m = n * (n as f64).log2() as usize;
    for threads in [1usize, 4] {
        let exec = if threads == 1 {
            FactorExec::serial()
        } else {
            FactorExec { threads, min_work: 0 }
        };
        let opts = GeneralOptions { max_sweeps: 0, exec, ..Default::default() };
        let t = bench(&format!("gen init n={n} m={m} threads={threads}"), 3, 0.3, || {
            GeneralFactorizer::new(&c, m, opts.clone()).run().objective()
        });
        println!("{}  ({:.0} factors/s)", t.line(), m as f64 / t.min_s);
    }
    // substrate: eigensolver
    for n in [128usize, 256, 512] {
        let mut rng = Rng64::new(7);
        let x = Mat::randn(n, n, &mut rng);
        let s = &x + &x.transpose();
        let t = bench(&format!("eigh n={n}"), 3, 0.3, || eigh(&s).values[0]);
        println!("{}", t.line());
    }
    // end-to-end: apply the factored GFT on the pooled serving hot path
    // (the artifact the factorization exists to produce)
    let n = 256;
    let mut rng = Rng64::new(8);
    let graph = graphs::community(n, &mut rng);
    let l = graph.laplacian();
    let g = 2 * n * (n as f64).log2() as usize;
    let f =
        SymFactorizer::new(&l, g, SymOptions { max_sweeps: 1, ..Default::default() }).run();
    let plan = f.plan();
    let pool = ExecPolicy::pool();
    let batch = 64;
    let signals: Vec<Vec<f32>> =
        (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
    let mut blk = SignalBlock::from_signals(&signals).unwrap();
    let t = bench(&format!("factored pooled apply n={n} batch={batch}"), 5, 0.1, || {
        plan.apply(&mut blk, Direction::Forward, &pool).unwrap();
        blk.data[0]
    });
    println!("{}  ({:.1} ns/signal)", t.line(), t.min_s * 1e9 / batch as f64);
}
