//! Fig.-6 bench: butterfly apply vs dense mat-vec at the paper's
//! real-graph sizes, f32, single vector, one core — plus the parallel
//! engines, all driven through the one `FastOperator` + `ExecPolicy`
//! surface.
//!
//! Run with: `cargo bench --bench apply_speedup`

use fastes::bench_util::bench;
use fastes::cli::figures::{budget, random_gplan, random_tplan};
use fastes::graphs::RealWorldGraph;
use fastes::linalg::Rng64;
use fastes::plan::{Direction, ExecPolicy, FastOperator, Plan};
use fastes::transforms::{default_threads, ExecConfig, SignalBlock};

fn main() {
    println!("# apply_speedup — butterfly vs dense mat-vec (f32, 1 vector, 1 core)");
    let alpha = 2usize;
    let seq = ExecPolicy::Seq;
    let mut rng = Rng64::new(99);
    for w in RealWorldGraph::all() {
        let (n, _) = w.dimensions();
        let g = budget(alpha, n);
        let gplan = Plan::from(random_gplan(n, g, &mut rng)).build();
        let tplan = Plan::from(random_tplan(n, g, &mut rng)).build();
        let dense: Vec<f32> = (0..n * n).map(|_| rng.randn() as f32).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();

        let mut y = vec![0f32; n];
        let td = bench(&format!("{}/dense-gemv n={n}", w.name()), 7, 0.05, || {
            for (r, yr) in y.iter_mut().enumerate() {
                let row = &dense[r * n..(r + 1) * n];
                let mut acc = 0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                *yr = acc;
            }
            y[0]
        });
        let mut blk = SignalBlock::from_signals(&[x.clone()]).unwrap();
        let tg = bench(&format!("{}/G-chain g={g}", w.name()), 7, 0.05, || {
            gplan.apply(&mut blk, Direction::Forward, &seq).unwrap();
            blk.data[0]
        });
        let mut blk2 = SignalBlock::from_signals(&[x.clone()]).unwrap();
        let tt = bench(&format!("{}/T-chain m={g}", w.name()), 7, 0.05, || {
            tplan.apply(&mut blk2, Direction::Forward, &seq).unwrap();
            blk2.data[0]
        });
        println!("{}", td.line());
        println!("{}", tg.line());
        println!("{}", tt.line());
        println!(
            "{:<14} flopx(G)={:<8.2} measured(G)={:<8.2} flopx(T)={:<8.2} measured(T)={:<8.2}",
            w.name(),
            (2 * n * n) as f64 / (6 * g) as f64,
            td.min_s / tg.min_s,
            (2 * n * n) as f64 / (2 * g) as f64,
            td.min_s / tt.min_s,
        );
    }
    // batched-apply scaling: the serving hot path
    println!("\n# batched apply (n=128, g=1792) — serving hot path");
    let n = 128;
    let g = budget(2, n);
    let plan = Plan::from(random_gplan(n, g, &mut rng)).build();
    for batch in [1usize, 4, 8, 32, 128] {
        let signals: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
        let mut blk = SignalBlock::from_signals(&signals).unwrap();
        let t = bench(&format!("batch={batch}"), 7, 0.05, || {
            plan.apply(&mut blk, Direction::Forward, &seq).unwrap();
            blk.data[0]
        });
        println!("{}  ({:.1} ns/signal)", t.line(), t.min_s * 1e9 / batch as f64);
    }

    // level-scheduled parallel apply vs the sequential engine
    let threads = default_threads();
    let spawn = ExecPolicy::spawn();
    println!("\n# level-scheduled parallel apply ({threads} threads available)");
    for n in [256usize, 1024] {
        let g = budget(2, n);
        let plan = Plan::from(random_gplan(n, g, &mut rng)).build();
        let st = plan.stats();
        println!(
            "n={n} g={g}: {} layers, depth-reduction {:.1}x, max width {}",
            st.layers, st.mean_width, st.max_width
        );
        // batch=1 at these sizes falls below the executor's work gates and
        // runs inline by design, so only real batch sizes are shown here;
        // the single-signal rotation-parallel mode is measured below.
        for batch in [32usize, 128] {
            let signals: Vec<Vec<f32>> =
                (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
            let mut seq_blk = SignalBlock::from_signals(&signals).unwrap();
            let t_seq = bench(&format!("n={n} batch={batch} sequential"), 7, 0.05, || {
                plan.apply(&mut seq_blk, Direction::Forward, &seq).unwrap();
                seq_blk.data[0]
            });
            let mut par_blk = SignalBlock::from_signals(&signals).unwrap();
            let t_par =
                bench(&format!("n={n} batch={batch} scheduled/{threads}t"), 7, 0.05, || {
                    plan.apply(&mut par_blk, Direction::Forward, &spawn).unwrap();
                    par_blk.data[0]
                });
            println!("{}", t_seq.line());
            println!("{}", t_par.line());
            println!(
                "n={n} batch={batch}: scheduled speedup {:.2}x over sequential",
                t_seq.min_s / t_par.min_s
            );
        }
    }

    // persistent-pool apply vs spawn-per-apply: the pool removes the
    // per-call thread spawn/join that dominates serve-sized requests, and
    // the fused cache-blocked streams cut the per-stage constant factor
    println!("\n# pooled apply vs spawn-per-apply ({threads} threads)");
    let pool = ExecPolicy::pool();
    for n in [256usize, 512] {
        let g = budget(2, n);
        let plan = Plan::from(random_gplan(n, g, &mut rng)).build();
        for batch in [8usize, 64] {
            let signals: Vec<Vec<f32>> =
                (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
            let mut seq_blk = SignalBlock::from_signals(&signals).unwrap();
            let t_seq = bench(&format!("n={n} batch={batch} sequential"), 7, 0.05, || {
                plan.apply(&mut seq_blk, Direction::Forward, &seq).unwrap();
                seq_blk.data[0]
            });
            let mut sp_blk = SignalBlock::from_signals(&signals).unwrap();
            let t_spawn = bench(&format!("n={n} batch={batch} spawn/{threads}t"), 7, 0.05, || {
                plan.apply(&mut sp_blk, Direction::Forward, &spawn).unwrap();
                sp_blk.data[0]
            });
            let mut pl_blk = SignalBlock::from_signals(&signals).unwrap();
            let t_pool = bench(&format!("n={n} batch={batch} pooled/{threads}t"), 7, 0.05, || {
                plan.apply(&mut pl_blk, Direction::Forward, &pool).unwrap();
                pl_blk.data[0]
            });
            println!("{}", t_seq.line());
            println!("{}", t_spawn.line());
            println!("{}", t_pool.line());
            println!(
                "n={n} batch={batch}: pooled {:.2}x vs sequential, {:.2}x vs spawn",
                t_seq.min_s / t_pool.min_s,
                t_spawn.min_s / t_pool.min_s
            );
        }
    }

    // single-signal rotation-parallel mode: engages only when mean layer
    // width × batch crosses the layer gate — random α·n·log n chains have
    // narrower layers and deliberately fall back to the inline path, so
    // the mode is measured on a synthetic wide-layer chain (rounds of n/2
    // disjoint butterflies)
    println!("\n# single-signal layer-parallel apply (synthetic wide layers, n=8192)");
    let n = 8192;
    let rounds = 64;
    let mut wide = fastes::transforms::GChain::identity(n);
    for r in 0..rounds {
        for k in 0..n / 2 {
            let th = 0.1 + 0.01 * ((r * k) % 23) as f64;
            wide.transforms.push(fastes::transforms::GTransform::new(
                2 * k,
                2 * k + 1,
                th.cos(),
                th.sin(),
                fastes::transforms::GKind::Rotation,
            ));
        }
    }
    let g = wide.len();
    let plan = Plan::from(wide).build();
    let st = plan.stats();
    println!(
        "n={n} g={g}: {} layers, mean width {:.1} (layer-parallel engages above {})",
        st.layers,
        st.mean_width,
        ExecConfig::spawn().layer_min_work
    );
    let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
    let mut seq_blk = SignalBlock::from_signals(&[x.clone()]).unwrap();
    let t_seq = bench("n=8192 batch=1 sequential", 5, 0.1, || {
        plan.apply(&mut seq_blk, Direction::Forward, &seq).unwrap();
        seq_blk.data[0]
    });
    let mut par_blk = SignalBlock::from_signals(&[x]).unwrap();
    let t_par = bench(&format!("n=8192 batch=1 scheduled/{threads}t"), 5, 0.1, || {
        plan.apply(&mut par_blk, Direction::Forward, &spawn).unwrap();
        par_blk.data[0]
    });
    println!("{}", t_seq.line());
    println!("{}", t_par.line());
    println!(
        "n={n} batch=1: scheduled speedup {:.2}x over sequential",
        t_seq.min_s / t_par.min_s
    );
}
