//! Fig.-6 bench: butterfly apply vs dense mat-vec at the paper's
//! real-graph sizes, f32, single vector, one core. Prints measured times,
//! the FLOP-count ratio and the measured speedup.
//!
//! Run with: `cargo bench --bench apply_speedup`

use fastes::bench_util::bench;
use fastes::cli::figures::{budget, random_gplan, random_tplan};
use fastes::graphs::RealWorldGraph;
use fastes::linalg::Rng64;
use fastes::transforms::{apply_gchain_batch_f32, apply_tchain_batch_f32, SignalBlock};

fn main() {
    println!("# apply_speedup — butterfly vs dense mat-vec (f32, 1 vector, 1 core)");
    let alpha = 2usize;
    let mut rng = Rng64::new(99);
    for w in RealWorldGraph::all() {
        let (n, _) = w.dimensions();
        let g = budget(alpha, n);
        let gplan = random_gplan(n, g, &mut rng).to_plan();
        let tplan = random_tplan(n, g, &mut rng).to_plan();
        let dense: Vec<f32> = (0..n * n).map(|_| rng.randn() as f32).collect();
        let x: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();

        let mut y = vec![0f32; n];
        let td = bench(&format!("{}/dense-gemv n={n}", w.name()), 7, 0.05, || {
            for (r, yr) in y.iter_mut().enumerate() {
                let row = &dense[r * n..(r + 1) * n];
                let mut acc = 0f32;
                for (a, b) in row.iter().zip(x.iter()) {
                    acc += a * b;
                }
                *yr = acc;
            }
            y[0]
        });
        let mut blk = SignalBlock::from_signals(&[x.clone()]);
        let tg = bench(&format!("{}/G-chain g={g}", w.name()), 7, 0.05, || {
            apply_gchain_batch_f32(&gplan, &mut blk);
            blk.data[0]
        });
        let mut blk2 = SignalBlock::from_signals(&[x.clone()]);
        let tt = bench(&format!("{}/T-chain m={g}", w.name()), 7, 0.05, || {
            apply_tchain_batch_f32(&tplan, &mut blk2, false);
            blk2.data[0]
        });
        println!("{}", td.line());
        println!("{}", tg.line());
        println!("{}", tt.line());
        println!(
            "{:<14} flopx(G)={:<8.2} measured(G)={:<8.2} flopx(T)={:<8.2} measured(T)={:<8.2}",
            w.name(),
            (2 * n * n) as f64 / (6 * g) as f64,
            td.min_s / tg.min_s,
            (2 * n * n) as f64 / (2 * g) as f64,
            td.min_s / tt.min_s,
        );
    }
    // batched-apply scaling: the serving hot path
    println!("\n# batched apply (n=128, g=1792) — serving hot path");
    let n = 128;
    let g = budget(2, n);
    let plan = random_gplan(n, g, &mut rng).to_plan();
    for batch in [1usize, 4, 8, 32, 128] {
        let signals: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..n).map(|_| rng.randn() as f32).collect()).collect();
        let mut blk = SignalBlock::from_signals(&signals);
        let t = bench(&format!("batch={batch}"), 7, 0.05, || {
            apply_gchain_batch_f32(&plan, &mut blk);
            blk.data[0]
        });
        println!("{}  ({:.1} ns/signal)", t.line(), t.min_s * 1e9 / batch as f64);
    }
}
