//! Serving-coordinator bench: end-to-end request throughput and latency
//! for the native backend across batch limits and execution policies,
//! plus the PJRT backend when artifacts are present.
//!
//! Run with: `cargo bench --bench serve_throughput`

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use fastes::cli::figures::{budget, random_gplan};
use fastes::linalg::Rng64;
use fastes::plan::{ExecPolicy, Plan};
use fastes::runtime::ArtifactStore;
use fastes::serve::{
    Backend, Coordinator, NativeGftBackend, PjrtGftBackend, ServeConfig, TransformDirection,
};

fn drive(coord: &Coordinator, n: usize, requests: usize, seed: u64) -> f64 {
    let mut rng = Rng64::new(seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(256);
    for _ in 0..requests {
        let sig: Vec<f32> = (0..n).map(|_| rng.randn() as f32).collect();
        pending.push(coord.submit(sig).unwrap());
        if pending.len() == 256 {
            for t in pending.drain(..) {
                t.wait().unwrap();
            }
        }
    }
    for t in pending.drain(..) {
        t.wait().unwrap();
    }
    requests as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("# serve_throughput — coordinator end-to-end");
    let n = 128;
    let g = budget(2, n);
    let mut rng = Rng64::new(31);
    let chain = random_gplan(n, g, &mut rng);
    let plan = Plan::from(&chain).build();

    for max_batch in [1usize, 4, 8, 32] {
        let p = Arc::clone(&plan);
        let coord = Coordinator::start(
            move || {
                Ok(Box::new(NativeGftBackend::with_policy(
                    p,
                    TransformDirection::Forward,
                    max_batch,
                    None,
                    ExecPolicy::Seq,
                )?) as Box<dyn Backend>)
            },
            ServeConfig { max_batch, ..Default::default() },
        )
        .unwrap();
        let rps = drive(&coord, n, 20_000, 32);
        let m = coord.shutdown();
        println!(
            "native  max_batch={max_batch:<3} {rps:>10.0} req/s  p50={:>8.1}µs p99={:>8.1}µs mean_batch={:.2}",
            m.p50_latency_s * 1e6,
            m.p99_latency_s * 1e6,
            m.mean_batch
        );
    }

    // pooled backend: same coordinator and plan, but every batch executes
    // on the process-wide persistent worker pool (fused, cache-blocked)
    for max_batch in [8usize, 32] {
        let p = Arc::clone(&plan);
        let coord = Coordinator::start(
            move || {
                Ok(Box::new(NativeGftBackend::with_policy(
                    p,
                    TransformDirection::Forward,
                    max_batch,
                    None,
                    ExecPolicy::pool(),
                )?) as Box<dyn Backend>)
            },
            ServeConfig { max_batch, ..Default::default() },
        )
        .unwrap();
        let rps = drive(&coord, n, 20_000, 34);
        let m = coord.shutdown();
        println!(
            "pooled  max_batch={max_batch:<3} {rps:>10.0} req/s  p50={:>8.1}µs p99={:>8.1}µs mean_batch={:.2}",
            m.p50_latency_s * 1e6,
            m.p99_latency_s * 1e6,
            m.mean_batch
        );
    }

    if Path::new("artifacts/manifest.txt").exists() {
        let arrays = chain.to_plan();
        let coord = Coordinator::start(
            move || {
                let store = ArtifactStore::open(Path::new("artifacts"))?;
                Ok(
                    Box::new(PjrtGftBackend::new(
                        store,
                        TransformDirection::Forward,
                        arrays,
                        8,
                        None,
                    )?) as Box<dyn Backend>,
                )
            },
            ServeConfig { max_batch: 8, ..Default::default() },
        )
        .unwrap();
        let rps = drive(&coord, n, 500, 33);
        let m = coord.shutdown();
        println!(
            "pjrt    max_batch=8   {rps:>10.0} req/s  p50={:>8.1}µs p99={:>8.1}µs mean_batch={:.2}",
            m.p50_latency_s * 1e6,
            m.p99_latency_s * 1e6,
            m.mean_batch
        );
    } else {
        println!("pjrt    skipped (run `make artifacts`)");
    }
}
