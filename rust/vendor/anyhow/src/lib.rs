//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides exactly the subset of the `anyhow` API the `fastes` codebase
//! uses: the [`Error`] type (a dynamic error with a context chain), the
//! [`Result`] alias, the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait. Errors are stored as a flattened chain of
//! messages (outermost context first) plus the original error value,
//! which [`Error::downcast_ref`] can recover (so typed errors like
//! `factor::ResumeError` survive `?`-conversion and added context).

use std::error::Error as StdError;
use std::fmt;

/// Dynamic error type: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
    /// The original typed error (when built via `From<E: StdError>`),
    /// kept for [`Self::downcast_ref`]. `None` for message-only errors.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

/// `Result<T, anyhow::Error>` alias, matching the real crate's signature.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Wrap with an additional layer of context (becomes the new outermost
    /// message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Recover the original typed error, if this `Error` was converted
    /// from one (context layers added afterwards don't hide it) — the
    /// subset of real anyhow's downcasting the codebase relies on.
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like the real anyhow
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Context::context(Err::<(), _>(io_err()), "opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing file");
    }

    #[test]
    fn macros_work() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("value {n} and {}", 4);
        assert_eq!(e.to_string(), "value 3 and 4");
        let owned = String::from("owned message");
        let e = anyhow!(owned);
        assert_eq!(e.to_string(), "owned message");
        fn fails() -> Result<()> {
            bail!("boom {}", 7)
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn downcast_ref_recovers_typed_errors_through_context() {
        let e: Error = Error::from(io_err()).context("opening config");
        let io = e.downcast_ref::<std::io::Error>().expect("typed error survives context");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<fmt::Error>().is_none());
        // message-only errors carry no payload
        assert!(anyhow!("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }
}
