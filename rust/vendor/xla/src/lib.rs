//! Offline stub of the `xla` PJRT bindings.
//!
//! The container that builds this repository has no PJRT runtime and no
//! crates.io access, so this crate provides the exact type/method surface
//! `fastes::runtime` compiles against. Every entry point that would touch
//! a real PJRT client returns [`Error`] with an "unavailable" message, so
//! the native rust backend remains the serving path and the PJRT
//! integration tests (which skip themselves when no AOT artifacts exist)
//! degrade gracefully.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' opaque error.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!("{what}: PJRT runtime is not available in this offline build (xla stub)"))
}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Would create a CPU PJRT client; unavailable in the stub.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Would compile an XLA computation; unavailable in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub compiled executable (never constructible through the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Would execute on device buffers; unavailable in the stub.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Would copy the buffer back to a host literal; unavailable.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Would parse HLO text; unavailable in the stub.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a module proto (shape-only operation, succeeds).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub host literal.
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal (shape-only stand-in, succeeds).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape (shape-only stand-in, succeeds).
    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(self)
    }

    /// Would unpack a 1-tuple; unavailable in the stub.
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Would copy out the host data; unavailable in the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("unavailable") || e.0.contains("not available"));
    }

    #[test]
    fn literal_shape_ops_succeed() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]);
        assert!(l.is_ok());
    }
}
