//! Fast graph Fourier transform on an undirected community graph:
//! factor the Laplacian with G-transforms, compare against the exact
//! eigendecomposition, and run a spectral low-pass filter through the
//! fast path.
//!
//! Run with: `cargo run --release --example gft_undirected`

use fastes::factor::{SymFactorizer, SymOptions};
use fastes::graphs;
use fastes::linalg::{eigh, Rng64};

fn main() {
    let n = 256;
    let mut rng = Rng64::new(42);
    let graph = graphs::community(n, &mut rng);
    let l = graph.laplacian();
    println!("community graph: n={n}, |E|={}", graph.num_edges());

    // exact GFT for reference
    let exact = eigh(&l);

    // fast approximate GFT at increasing budgets
    for alpha in [1usize, 2, 4] {
        let g = alpha * n * (n as f64).log2() as usize;
        let f = SymFactorizer::new(&l, g, SymOptions::default()).run();
        println!(
            "alpha={alpha}: g={:<6} rel_err(L)={:.4}  flops {} vs dense {}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n
        );
    }

    // spectral filtering through the factored transform:
    // y = Ū h(λ̄) Ūᵀ x with a heat-kernel low-pass h(λ) = exp(−τλ)
    let g = 2 * n * (n as f64).log2() as usize;
    let f = SymFactorizer::new(&l, g, SymOptions::default()).run();
    let tau = 0.5 / exact.values[0].max(1e-9);
    let x: Vec<f64> = (0..n).map(|_| rng.randn()).collect();

    let mut fast = x.clone();
    f.chain.apply_vec_t(&mut fast);
    for (v, lam) in fast.iter_mut().zip(f.spectrum.iter()) {
        *v *= (-tau * lam.max(0.0)).exp();
    }
    f.chain.apply_vec(&mut fast);

    // exact filtering for comparison
    let mut xhat = exact.vectors.tmatvec(&x);
    for (v, lam) in xhat.iter_mut().zip(exact.values.iter()) {
        *v *= (-tau * lam.max(0.0)).exp();
    }
    let exact_y = exact.vectors.matvec(&xhat);

    let num: f64 = fast
        .iter()
        .zip(exact_y.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt();
    let den: f64 = exact_y.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("heat-kernel filter: relative deviation from exact GFT filter {:.4}", num / den);
}
