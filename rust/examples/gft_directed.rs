//! Fast graph Fourier transform on a *directed* graph: the Laplacian is
//! unsymmetric, so the eigenspace is factored with scaling/shear
//! T-transforms (paper §4.2). Demonstrates the invertible fast path
//! `T̄ diag(c̄) T̄⁻¹`.
//!
//! Run with: `cargo run --release --example gft_directed`

use fastes::factor::{GeneralFactorizer, GeneralOptions};
use fastes::graphs;
use fastes::linalg::Rng64;

fn main() {
    let n = 96;
    let mut rng = Rng64::new(11);
    let undirected = graphs::erdos_renyi(n, 0.3, &mut rng);
    let graph = undirected.randomly_directed(&mut rng);
    let l = graph.laplacian();
    println!("directed Erdős–Rényi: n={n}, |E|={}", graph.num_edges());

    for alpha in [1usize, 2, 3] {
        let m = alpha * n * (n as f64).log2() as usize;
        let f = GeneralFactorizer::new(&l, m, GeneralOptions::default()).run();
        println!(
            "alpha={alpha}: m={:<6} rel_err(L)={:.4}  flops {} vs dense {}",
            f.chain.len(),
            f.relative_error(&l),
            f.chain.flops(),
            2 * n * n
        );

        // fast directed-GFT round trip: x → T̄⁻¹x (analysis) → T̄ (synthesis)
        let x: Vec<f64> = (0..n).map(|_| rng.randn()).collect();
        let mut y = x.clone();
        f.chain.apply_vec_inv(&mut y);
        f.chain.apply_vec(&mut y);
        let max_dev =
            x.iter().zip(y.iter()).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        println!("  analysis∘synthesis round-trip max deviation {max_dev:.2e}");
        assert!(max_dev < 1e-6, "T̄ must stay invertible");
    }
}
