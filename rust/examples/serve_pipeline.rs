//! End-to-end driver (DESIGN.md §6): the full three-layer system on a real
//! small workload.
//!
//! 1. generate a community graph (n = 128) and its Laplacian;
//! 2. factor the Laplacian into a fast GFT with Algorithm 1 (L3 rust);
//! 3. start the serving coordinator twice — once on the **native** rust
//!    butterfly fast path and once on the **PJRT artifact** compiled from
//!    the JAX (L2) + Pallas (L1) model by `make artifacts`;
//! 4. submit thousands of batched spectral-filtering / GFT requests;
//! 5. report p50/p99 latency, throughput, and the numerical agreement
//!    between the two backends and the exact dense transform.
//!
//! Run with: `make artifacts && cargo run --release --example serve_pipeline`

use std::path::Path;
use std::time::Instant;

use fastes::factor::{SymFactorizer, SymOptions};
use fastes::graphs;
use fastes::linalg::Rng64;
use fastes::plan::ExecPolicy;
use fastes::runtime::ArtifactStore;
use fastes::serve::{
    Backend, Coordinator, NativeGftBackend, PjrtGftBackend, ServeConfig, TransformDirection,
};

const N: usize = 128;
const BATCH: usize = 8;
const REQUESTS: usize = 4000;

fn drive(coordinator: &Coordinator, rng: &mut Rng64, label: &str) -> Vec<Vec<f32>> {
    let t0 = Instant::now();
    let mut outputs = Vec::with_capacity(REQUESTS);
    let mut pending = Vec::with_capacity(128);
    for _ in 0..REQUESTS {
        let sig: Vec<f32> = (0..N).map(|_| rng.randn() as f32).collect();
        pending.push(coordinator.submit(sig).expect("submit"));
        if pending.len() == 128 {
            for t in pending.drain(..) {
                outputs.push(t.wait().expect("response"));
            }
        }
    }
    for t in pending.drain(..) {
        outputs.push(t.wait().expect("response"));
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coordinator.metrics();
    println!(
        "[{label}] {} req in {dt:.2}s → {:.0} req/s | p50 {:.1}µs p99 {:.1}µs | mean batch {:.2}",
        REQUESTS,
        REQUESTS as f64 / dt,
        m.p50_latency_s * 1e6,
        m.p99_latency_s * 1e6,
        m.mean_batch,
    );
    outputs
}

fn main() {
    // --- 1+2: graph + factorization (L3) ---------------------------------
    let mut rng = Rng64::new(2021);
    let graph = graphs::community(N, &mut rng);
    let l = graph.laplacian();
    let g = 2 * N * (N as f64).log2() as usize;
    println!("factoring community graph n={N} |E|={} with g={g}…", graph.num_edges());
    let t0 = Instant::now();
    let f = SymFactorizer::new(&l, g, SymOptions::default()).run();
    println!(
        "factored in {:.2?}: rel_err(L) = {:.4}, {} flops/apply vs {} dense",
        t0.elapsed(),
        f.relative_error(&l),
        f.chain.flops(),
        2 * N * N
    );
    let plan = f.plan();
    let arrays = f.chain.to_plan();

    // --- 3+4: serve on the native backend (pooled ExecPolicy) ------------
    let cfg = ServeConfig { max_batch: BATCH, ..Default::default() };
    let p = plan.clone();
    let native = Coordinator::start(
        move || {
            Ok(Box::new(NativeGftBackend::with_policy(
                p,
                TransformDirection::Forward,
                BATCH,
                None,
                ExecPolicy::pool(),
            )?) as Box<dyn Backend>)
        },
        cfg.clone(),
    )
    .expect("native coordinator");
    let mut rng_a = Rng64::new(777);
    let native_out = drive(&native, &mut rng_a, "native ");
    native.shutdown();

    // --- 3+4 again: serve on the PJRT artifact (L1+L2 via AOT) -----------
    if !Path::new("artifacts/manifest.txt").exists() {
        println!("[pjrt   ] skipped — run `make artifacts` first");
        return;
    }
    let p = arrays.clone();
    let pjrt = Coordinator::start(
        move || {
            let store = ArtifactStore::open(Path::new("artifacts"))?;
            Ok(Box::new(PjrtGftBackend::new(store, TransformDirection::Forward, p, BATCH, None)?)
                as Box<dyn Backend>)
        },
        cfg,
    )
    .expect("pjrt coordinator");
    let mut rng_b = Rng64::new(777); // same request stream
    let pjrt_out = drive(&pjrt, &mut rng_b, "pjrt   ");
    pjrt.shutdown();

    // --- 5: cross-validate the two stacks + the exact dense transform ----
    let mut max_dev = 0f32;
    for (a, b) in native_out.iter().zip(pjrt_out.iter()) {
        for (x, y) in a.iter().zip(b.iter()) {
            max_dev = max_dev.max((x - y).abs());
        }
    }
    println!("native vs pjrt max deviation over {} outputs: {max_dev:.2e}", native_out.len());
    assert!(max_dev < 1e-3, "backends disagree");

    // exact check on a fresh signal: Ūᵀx via dense chain
    let mut rng_c = Rng64::new(777);
    let sig: Vec<f32> = (0..N).map(|_| rng_c.randn() as f32).collect();
    let mut want: Vec<f64> = sig.iter().map(|&v| v as f64).collect();
    f.chain.apply_vec_t(&mut want);
    let got = &native_out[0];
    let mut dev = 0f32;
    for (w, o) in want.iter().zip(got.iter()) {
        dev = dev.max((*w as f32 - o).abs());
    }
    println!("native vs f64 reference max deviation: {dev:.2e}");
    assert!(dev < 1e-3);
    println!("serve_pipeline OK — all three layers agree");
}
