//! Quickstart: factor a random symmetric matrix into G-transforms and a
//! random general matrix into T-transforms, then use the fast apply.
//!
//! Run with: `cargo run --release --example quickstart`

use fastes::factor::{GeneralFactorizer, GeneralOptions, SymFactorizer, SymOptions};
use fastes::linalg::{Mat, Rng64};

fn main() {
    let n = 128;
    let mut rng = Rng64::new(7);

    // --- symmetric case: S ≈ Ū diag(s̄) Ūᵀ --------------------------------
    let x = Mat::randn(n, n, &mut rng);
    let s = &x + &x.transpose();
    // budget: g = 2·n·log₂n extended Givens factors
    let g = 2 * n * (n as f64).log2() as usize;
    let f = SymFactorizer::new(&s, g, SymOptions::default()).run();
    println!(
        "symmetric n={n}: g={} factors, relative error {:.4}",
        f.chain.len(),
        f.relative_error(&s)
    );
    println!(
        "  fast apply: {} flops vs {} dense ({}x fewer)",
        f.chain.flops(),
        2 * n * n,
        (2 * n * n) as f64 / f.chain.flops().max(1) as f64
    );

    // multiply a vector by the approximation: Ū diag(s̄) Ūᵀ x — O(g + n)
    let mut v: Vec<f64> = (0..n).map(|_| rng.randn()).collect();
    let dense_result = {
        let approx = f.chain.reconstruct(&f.spectrum);
        approx.matvec(&v)
    };
    f.chain.apply_vec_t(&mut v);
    for (vi, si) in v.iter_mut().zip(f.spectrum.iter()) {
        *vi *= si;
    }
    f.chain.apply_vec(&mut v);
    let max_dev = v
        .iter()
        .zip(dense_result.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("  fast-path vs dense reconstruction: max deviation {max_dev:.2e}");
    assert!(max_dev < 1e-8);

    // --- general case: C ≈ T̄ diag(c̄) T̄⁻¹ ---------------------------------
    let c = Mat::randn(64, 64, &mut rng);
    let m = 2 * 64 * 6;
    let fg = GeneralFactorizer::new(&c, m, GeneralOptions::default()).run();
    println!(
        "general n=64: m={} factors, relative error {:.4}, {} flops/apply",
        fg.chain.len(),
        fg.relative_error(&c),
        fg.chain.flops()
    );
}
