#!/usr/bin/env python3
"""Compare a fresh bench artifact against the checked-in snapshot.

Usage: check_bench_regression.py BENCH_apply.json ci/bench_snapshot.json
       check_bench_regression.py BENCH_factor.json ci/factor_snapshot.json
       check_bench_regression.py BENCH_error.json ci/error_snapshot.json

The artifact's top-level `bench` field ("apply" — the default when the
field is absent — "factor", "error", or "refactor") selects the
comparison: apply artifacts gate pooled ns/stage per size, factor
artifacts gate ns/step per (kind, n, threads) row, error artifacts gate
the bake-off's certified rel_err per (family, method, g) row, and
refactor artifacts gate the warm-vs-cold sweeps ratio per (family, n)
row (warm-starting a drifted graph must keep beating a cold
refactorization). The snapshot must be of the same kind.

Fails (exit 1) when any compared number regresses more than the
snapshot's `max_regression` factor — but only once the snapshot is
calibrated (`calibrated: true`); until then the comparison is printed as
advisory so the gate cannot fail on un-measured placeholder numbers.

Once calibrated, the gate also refuses to pass silently on a broken
input: a missing artifact, a kind mismatch, or an apply artifact without
the `kernel_isa` field (perf numbers are only comparable when we know
which SIMD kernel produced them) is a hard failure with an actionable
message.
"""

import json
import os
import sys


def check_factor(bench, snap, calibrated, limit):
    """Gate a BENCH_factor.json: ns/step per (kind, n, threads) row.

    Prints the envelope actually enforced per row (baseline x limit) so
    a CI log shows how much headroom each measurement had, not just the
    pass/fail verdict.
    """
    baseline = snap.get("factor_ns_per_step", {})
    failures = []
    for row in bench["results"]:
        key = f"{row['kind']}/{row['n']}/{row['threads']}"
        now = float(row["ns_per_step"])
        base = baseline.get(key)
        if base is None:
            print(f"{key}: {now:.1f} ns/step (no baseline for this key — advisory)")
            continue
        envelope = float(base) * limit
        ratio = now / float(base)
        status = "OK" if ratio <= limit else "REGRESSION"
        print(
            f"{key}: {now:.1f} ns/step vs baseline {float(base):.1f} "
            f"— envelope <= {envelope:.1f} ns/step ({limit:.2f}x), "
            f"measured {ratio:.2f}x, headroom {envelope / now:.1f}x {status}"
        )
        if ratio > limit:
            failures.append(key)
    if failures and calibrated:
        print(f"factor ns/step regressed beyond {limit:.2f}x for {failures}")
        return 1
    if failures:
        print("regressions observed but snapshot is uncalibrated — advisory only")
    return 0


def check_error(bench, snap, calibrated, limit):
    """Gate a BENCH_error.json: certified rel_err per (family, method, g).

    The bake-off runs under a fixed seed, so accuracy is deterministic
    per runner-independent arithmetic — once calibrated the limit can
    sit close to 1.0x. Until then every row prints as advisory.
    """
    baseline = snap.get("rel_err", {})
    failures = []
    for row in bench["results"]:
        key = f"{row['family']}/{row['method']}/{row['g']}"
        now = float(row["rel_err"])
        base = baseline.get(key)
        if base is None:
            print(f"{key}: rel_err {now:.4e} (no baseline for this key — advisory)")
            continue
        envelope = float(base) * limit
        status = "OK" if now <= envelope else "REGRESSION"
        print(
            f"{key}: rel_err {now:.4e} vs baseline {float(base):.4e} "
            f"— envelope <= {envelope:.4e} ({limit:.2f}x) {status}"
        )
        if now > envelope:
            failures.append(key)
    if failures and calibrated:
        print(f"certified rel_err regressed beyond {limit:.2f}x for {failures}")
        return 1
    if failures:
        print("regressions observed but snapshot is uncalibrated — advisory only")
    return 0


def check_refactor(bench, snap, calibrated, limit):
    """Gate a BENCH_refactor.json: warm-vs-cold sweeps ratio per (family, n).

    The ratio is warm.total_sweeps / cold.total_sweeps for the same
    drifted graph at the same error budget — below 1.0 means the warm
    start reached the budget with less work. Both runs are fixed-seed
    deterministic, so once calibrated the envelope can sit close to
    1.0x. Independently of calibration, a row whose warm run misses the
    budget it claims to have met is a hard structural failure.
    """
    baseline = snap.get("warm_vs_cold_sweeps", {})
    failures = []
    broken = []
    for row in bench["results"]:
        key = f"{row['family']}/{row['n']}"
        ratio = float(row["warm_vs_cold_sweeps"])
        budget = float(row["budget"])
        for mode in ("cold", "warm"):
            if float(row[mode]["rel_err"]) > budget:
                broken.append(
                    f"{key}: {mode} run rel_err {float(row[mode]['rel_err']):.4e} "
                    f"misses its own budget {budget:.4e}"
                )
        base = baseline.get(key)
        if base is None:
            print(f"{key}: warm/cold sweeps {ratio:.3f} (no baseline for this key — advisory)")
            continue
        envelope = float(base) * limit
        status = "OK" if ratio <= envelope else "REGRESSION"
        print(
            f"{key}: warm/cold sweeps {ratio:.3f} vs baseline {float(base):.3f} "
            f"— envelope <= {envelope:.3f} ({limit:.2f}x) {status}"
        )
        if ratio > envelope:
            failures.append(key)
    for msg in broken:
        print(f"ERROR: {msg}")
    if broken:
        return 1
    if failures and calibrated:
        print(f"warm-vs-cold sweeps ratio regressed beyond {limit:.2f}x for {failures}")
        return 1
    if failures:
        print("regressions observed but snapshot is uncalibrated — advisory only")
    return 0


def main() -> int:
    bench_path, snap_path = sys.argv[1], sys.argv[2]
    snap = json.load(open(snap_path))
    limit = float(snap.get("max_regression", 1.25))
    calibrated = bool(snap.get("calibrated", False))
    baseline = snap.get("pooled_ns_per_stage", {})

    if not os.path.exists(bench_path):
        msg = (
            f"{bench_path} is missing — the bench smoke did not produce an artifact. "
            "Run `fastes bench --json --sizes 64 --batch 8 --min-work 1 "
            f"--out {bench_path}` (or check the 'Bench smoke' CI step logs)."
        )
        if calibrated:
            print(f"ERROR: {msg}")
            return 1
        print(f"advisory (snapshot uncalibrated): {msg}")
        return 0

    bench = json.load(open(bench_path))

    bench_kind = bench.get("bench", "apply")
    snap_kind = snap.get("bench", "apply")
    if bench_kind != snap_kind:
        print(
            f"ERROR: {bench_path} is a '{bench_kind}' bench but {snap_path} is a "
            f"'{snap_kind}' snapshot — the artifact and snapshot kinds do not match"
        )
        return 1
    if bench_kind == "factor":
        return check_factor(bench, snap, calibrated, limit)
    if bench_kind == "error":
        return check_error(bench, snap, calibrated, limit)
    if bench_kind == "refactor":
        return check_refactor(bench, snap, calibrated, limit)

    kernel = bench.get("kernel_isa")
    if not kernel:
        msg = (
            f"{bench_path} lacks the 'kernel_isa' field — pooled ns/stage numbers are "
            "only comparable against the snapshot when the dispatched SIMD kernel is "
            "recorded. Re-run the bench with a fastes binary that includes the SIMD "
            "dispatch layer (any build after the kernel_isa field landed)."
        )
        if calibrated:
            print(f"ERROR: {msg}")
            return 1
        print(f"advisory (snapshot uncalibrated): {msg}")
    kernel_comparable = True
    if kernel:
        print(f"kernel_isa: {kernel}")
        snap_kernel = snap.get("kernel_isa")
        if calibrated and not snap_kernel:
            kernel_comparable = False
            print(
                "note: snapshot is calibrated but records no kernel_isa — cannot tell "
                "whether this run's kernel matches the calibration, so the gate is "
                "advisory (add kernel_isa to the snapshot when recalibrating)"
            )
        elif snap_kernel and snap_kernel != kernel:
            kernel_comparable = False
            print(
                f"note: snapshot was calibrated on kernel_isa={snap_kernel}; "
                f"this run dispatched {kernel} — ns/stage deltas reflect the kernel, "
                "not a regression, so the gate is advisory for this run "
                "(recalibrate the snapshot to re-arm it for this runner class)"
            )

    # informational: surface the auto-tuned config the bench ran with
    # (never affects the gate — the compared column stays pooled ns/stage)
    autotune = bench.get("autotune")
    if autotune and autotune != "off":
        for row in bench.get("results", []):
            tuned = row.get("tuned")
            if tuned:
                print(
                    f"n={row['n']}: autotune({autotune}) chose {tuned['engine']}"
                    f"({tuned['threads']}t, tile {tuned['tile_cols']}, "
                    f"min_work {tuned['min_work']}, kernel {tuned['kernel']}) "
                    f"at {float(tuned['ns_per_stage']):.3f} ns/stage "
                    f"[{tuned.get('sweeps', '?')} startup sweeps]"
                )

    failures = []
    for row in bench["results"]:
        n = row["n"]
        now = float(row["pooled"]["ns_per_stage"])
        base = baseline.get(str(n))
        if base is None:
            print(f"n={n}: pooled {now:.3f} ns/stage (no baseline — snapshot uncalibrated)")
            continue
        ratio = now / float(base)
        status = "OK" if ratio <= limit else "REGRESSION"
        print(
            f"n={n}: pooled {now:.3f} ns/stage vs baseline {float(base):.3f} "
            f"({ratio:.2f}x, limit {limit:.2f}x) {status}"
        )
        if ratio > limit:
            failures.append(n)

    if failures and calibrated and kernel_comparable:
        print(f"pooled ns/stage regressed beyond {limit:.2f}x for sizes {failures}")
        return 1
    if failures and not kernel_comparable:
        print("regressions observed but the dispatched kernel differs from the "
              "snapshot's — advisory only (recalibrate to re-arm)")
    elif failures:
        print("regressions observed but snapshot is uncalibrated — advisory only")
    return 0


if __name__ == "__main__":
    sys.exit(main())
