#!/usr/bin/env python3
"""Compare a fresh BENCH_apply.json against the checked-in snapshot.

Usage: check_bench_regression.py BENCH_apply.json ci/bench_snapshot.json

Fails (exit 1) when the pooled ns/stage of any size regresses more than
the snapshot's `max_regression` factor — but only once the snapshot is
calibrated (`calibrated: true`); until then the comparison is printed as
advisory so the gate cannot fail on un-measured placeholder numbers.
"""

import json
import sys


def main() -> int:
    bench_path, snap_path = sys.argv[1], sys.argv[2]
    bench = json.load(open(bench_path))
    snap = json.load(open(snap_path))
    limit = float(snap.get("max_regression", 1.25))
    calibrated = bool(snap.get("calibrated", False))
    baseline = snap.get("pooled_ns_per_stage", {})

    failures = []
    for row in bench["results"]:
        n = row["n"]
        now = float(row["pooled"]["ns_per_stage"])
        base = baseline.get(str(n))
        if base is None:
            print(f"n={n}: pooled {now:.3f} ns/stage (no baseline — snapshot uncalibrated)")
            continue
        ratio = now / float(base)
        status = "OK" if ratio <= limit else "REGRESSION"
        print(
            f"n={n}: pooled {now:.3f} ns/stage vs baseline {float(base):.3f} "
            f"({ratio:.2f}x, limit {limit:.2f}x) {status}"
        )
        if ratio > limit:
            failures.append(n)

    if failures and calibrated:
        print(f"pooled ns/stage regressed beyond {limit:.2f}x for sizes {failures}")
        return 1
    if failures:
        print("regressions observed but snapshot is uncalibrated — advisory only")
    return 0


if __name__ == "__main__":
    sys.exit(main())
