#!/usr/bin/env python3
"""Unit checks for check_bench_regression.py, invoked from CI.

The bench-trajectory gate is now armed (ci/bench_snapshot.json ships
calibrated: true), so its decision logic is load-bearing: this script
pins the exit-code contract against synthetic inputs —

  * calibrated + matching kernel + regression beyond the limit -> fail
  * calibrated + matching kernel + within the limit            -> pass
  * calibrated + kernel mismatch + regression   -> advisory (pass)
  * calibrated + missing BENCH_apply.json       -> fail
  * calibrated + artifact without kernel_isa    -> fail
  * uncalibrated + regression                   -> advisory (pass)

and the factor-artifact path (BENCH_factor.json vs factor_snapshot.json,
dispatched on the documents' top-level `bench` field) —

  * uncalibrated factor snapshot                -> advisory (pass)
  * calibrated + ns/step regression             -> fail
  * calibrated + within the limit               -> pass
  * calibrated + key absent from the snapshot   -> advisory (pass)
  * artifact/snapshot kind mismatch             -> fail
  * every gated row prints its enforced envelope (baseline x limit)

and the error-artifact path (BENCH_error.json vs error_snapshot.json,
gating the bake-off's certified rel_err per family/method/g row) —

  * uncalibrated error snapshot                 -> advisory (pass)
  * calibrated + rel_err beyond the envelope    -> fail
  * calibrated + within the envelope            -> pass
  * error artifact against an apply snapshot    -> fail

and the refactor-artifact path (BENCH_refactor.json vs
refactor_snapshot.json, gating the warm-vs-cold sweeps ratio per
family/n row) —

  * uncalibrated refactor snapshot              -> advisory (pass)
  * calibrated + ratio beyond the envelope      -> fail
  * calibrated + within the envelope            -> pass
  * a run missing its own budget                -> fail even uncalibrated

Run: python3 ci/test_check_bench_regression.py
"""

import json
import os
import subprocess
import sys
import tempfile

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "check_bench_regression.py")


def snapshot(calibrated=True, kernel="avx2", baseline=10.0, limit=1.25):
    return {
        "calibrated": calibrated,
        "kernel_isa": kernel,
        "max_regression": limit,
        "pooled_ns_per_stage": {"64": baseline},
    }


def bench(pooled=10.0, kernel="avx2", tuned=True):
    row = {
        "n": 64,
        "pooled": {"ns_per_stage": pooled},
    }
    doc = {"bench": "apply", "results": [row]}
    if kernel is not None:
        doc["kernel_isa"] = kernel
    if tuned:
        doc["autotune"] = "quick"
        row["tuned"] = {
            "engine": "pool",
            "threads": 4,
            "tile_cols": 8,
            "min_work": 2048,
            "kernel": "auto",
            "sweeps": 5,
            "ns_per_stage": pooled,
        }
    return doc


def factor_snapshot(calibrated=False, baseline=None, limit=1.5):
    return {
        "bench": "factor",
        "calibrated": calibrated,
        "max_regression": limit,
        "factor_ns_per_step": baseline or {},
    }


def factor_bench(ns=100.0):
    return {
        "bench": "factor",
        "results": [
            {
                "kind": "sym",
                "n": 64,
                "budget": 128,
                "threads": 1,
                "steps": 130,
                "total_s": 0.01,
                "ns_per_step": ns,
                "steps_per_sec": 1e9 / ns,
                "rel_err": 0.3,
            }
        ],
    }


def error_snapshot(calibrated=False, baseline=None, limit=1.05):
    return {
        "bench": "error",
        "calibrated": calibrated,
        "max_regression": limit,
        "rel_err": baseline or {},
    }


def error_bench(rel=0.25):
    return {
        "bench": "error",
        "results": [
            {
                "family": "er",
                "method": "givens",
                "n": 32,
                "g": 160,
                "flops": 960,
                "rel_err": rel,
            }
        ],
    }


def refactor_snapshot(calibrated=False, baseline=None, limit=1.10):
    return {
        "bench": "refactor",
        "calibrated": calibrated,
        "max_regression": limit,
        "warm_vs_cold_sweeps": baseline or {},
    }


def refactor_bench(ratio=0.5, warm_rel=0.2, cold_rel=0.2, budget=0.25):
    def mode(rel, sweeps):
        return {
            "g": 96,
            "sweeps": sweeps,
            "growth_rounds": 0,
            "factors_added": 0,
            "rel_err": rel,
            "total_s": 0.01,
        }

    return {
        "bench": "refactor",
        "results": [
            {
                "family": "community",
                "n": 48,
                "budget": budget,
                "drift_steps": 6,
                "donor_g": 96,
                "cold": mode(cold_rel, 4),
                "warm": mode(warm_rel, 2),
                "warm_vs_cold_sweeps": ratio,
            }
        ],
    }


def run_case(name, bench_doc, snap_doc, want_exit, want_in_stdout=None):
    with tempfile.TemporaryDirectory() as tmp:
        snap_path = os.path.join(tmp, "snapshot.json")
        with open(snap_path, "w") as f:
            json.dump(snap_doc, f)
        bench_path = os.path.join(tmp, "BENCH_apply.json")
        if bench_doc is not None:
            with open(bench_path, "w") as f:
                json.dump(bench_doc, f)
        r = subprocess.run(
            [sys.executable, SCRIPT, bench_path, snap_path],
            capture_output=True,
            text=True,
        )
        ok = r.returncode == want_exit
        if ok and want_in_stdout is not None:
            ok = want_in_stdout in r.stdout
        status = "ok" if ok else "FAIL"
        print(f"[{status}] {name}: exit {r.returncode} (want {want_exit})")
        if not ok:
            print("---- stdout ----")
            print(r.stdout)
            print("---- stderr ----")
            print(r.stderr)
        return ok


def main() -> int:
    cases = [
        (
            "calibrated + matching kernel + regression fails",
            bench(pooled=20.0),
            snapshot(baseline=10.0),
            1,
            "REGRESSION",
        ),
        (
            "calibrated + matching kernel + within limit passes",
            bench(pooled=11.0),
            snapshot(baseline=10.0),
            0,
            "OK",
        ),
        (
            "cross-kernel regression downgrades to advisory",
            bench(pooled=20.0, kernel="avx512"),
            snapshot(baseline=10.0, kernel="avx2"),
            0,
            "advisory",
        ),
        (
            "calibrated + missing artifact fails",
            None,
            snapshot(),
            1,
            "missing",
        ),
        (
            "calibrated + artifact without kernel_isa fails",
            bench(pooled=10.0, kernel=None),
            snapshot(),
            1,
            "kernel_isa",
        ),
        (
            "uncalibrated regression stays advisory",
            bench(pooled=20.0),
            snapshot(calibrated=False, baseline=10.0),
            0,
            "advisory",
        ),
        (
            "tuned config is surfaced in the log",
            bench(pooled=11.0),
            snapshot(baseline=10.0),
            0,
            "autotune(quick) chose pool",
        ),
        (
            "factor: uncalibrated snapshot stays advisory",
            factor_bench(ns=100.0),
            factor_snapshot(),
            0,
            "no baseline",
        ),
        (
            "factor: calibrated ns/step regression fails",
            factor_bench(ns=200.0),
            factor_snapshot(calibrated=True, baseline={"sym/64/1": 100.0}),
            1,
            "REGRESSION",
        ),
        (
            "factor: calibrated within limit passes",
            factor_bench(ns=110.0),
            factor_snapshot(calibrated=True, baseline={"sym/64/1": 100.0}),
            0,
            "OK",
        ),
        (
            "factor artifact against apply snapshot fails",
            factor_bench(ns=100.0),
            snapshot(),
            1,
            "do not match",
        ),
        (
            "factor: the enforced envelope is printed per gated row",
            factor_bench(ns=110.0),
            factor_snapshot(calibrated=True, baseline={"sym/64/1": 100.0}),
            0,
            "envelope <= 150.0 ns/step",
        ),
        (
            "factor: calibrated snapshot missing a key stays advisory",
            factor_bench(ns=9e9),
            factor_snapshot(calibrated=True, baseline={"gen/32/4": 100.0}),
            0,
            "no baseline for this key",
        ),
        (
            "error: uncalibrated snapshot stays advisory",
            error_bench(rel=0.25),
            error_snapshot(),
            0,
            "no baseline",
        ),
        (
            "error: calibrated rel_err regression fails",
            error_bench(rel=0.30),
            error_snapshot(calibrated=True, baseline={"er/givens/160": 0.25}),
            1,
            "REGRESSION",
        ),
        (
            "error: calibrated within the envelope passes",
            error_bench(rel=0.255),
            error_snapshot(calibrated=True, baseline={"er/givens/160": 0.25}),
            0,
            "OK",
        ),
        (
            "error artifact against apply snapshot fails",
            error_bench(rel=0.25),
            snapshot(),
            1,
            "do not match",
        ),
        (
            "refactor: uncalibrated snapshot stays advisory",
            refactor_bench(ratio=0.5),
            refactor_snapshot(),
            0,
            "no baseline",
        ),
        (
            "refactor: calibrated ratio regression fails",
            refactor_bench(ratio=0.9),
            refactor_snapshot(calibrated=True, baseline={"community/48": 0.5}),
            1,
            "REGRESSION",
        ),
        (
            "refactor: calibrated within the envelope passes",
            refactor_bench(ratio=0.52),
            refactor_snapshot(calibrated=True, baseline={"community/48": 0.5}),
            0,
            "OK",
        ),
        (
            "refactor: a warm run missing its budget fails even uncalibrated",
            refactor_bench(ratio=0.5, warm_rel=0.4, budget=0.25),
            refactor_snapshot(),
            1,
            "misses its own budget",
        ),
    ]
    failed = 0
    for name, bench_doc, snap_doc, want_exit, want_out in cases:
        if not run_case(name, bench_doc, snap_doc, want_exit, want_out):
            failed += 1
    if failed:
        print(f"{failed}/{len(cases)} cases failed")
        return 1
    print(f"all {len(cases)} cases passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
