#!/usr/bin/env python3
"""Loopback smoke test for the hardened serving edge (`fastes serve --listen`).

Usage: serve_smoke.py --n N -- <fastes-binary> serve --plan X.fastplan \
           --listen 127.0.0.1:0 [more serve flags]

Launches the server command (the fastes binary directly — not through
`cargo run`, so the SIGTERM below reaches the server and the exit code
is the server's), parses the bound port from its "listening on" line,
then exercises the wire protocol end to end:

  1. `metrics` answers on a fresh connection
  2. `forward` on a deterministic signal returns an n-vector
  3. `adjoint` of that reply round-trips back to the input (the G-chain
     is orthonormal, so synthesis(analysis(x)) ~= x)
  4. `metrics` now reports both transforms completed and zero errors
  5. SIGTERM drains gracefully: the process prints "drained:" and
     exits 0 with every in-flight reply already delivered

Any hang is bounded by socket/process timeouts; any protocol or
drain failure exits non-zero with a diagnostic.
"""

import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

TIMEOUT = 120.0  # generous: debug builds on loaded CI runners


def send_frame(sock, obj):
    payload = json.dumps(obj).encode()
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_exact(sock, count):
    buf = b""
    while len(buf) < count:
        chunk = sock.recv(count - len(buf))
        if not chunk:
            raise ConnectionError(f"server closed mid-frame ({len(buf)}/{count} bytes)")
        buf += chunk
    return buf


def recv_frame(sock):
    (length,) = struct.unpack("<I", recv_exact(sock, 4))
    return json.loads(recv_exact(sock, length))


def request(sock, obj):
    send_frame(sock, obj)
    return recv_frame(sock)


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main():
    args = sys.argv[1:]
    if len(args) < 3 or args[0] != "--n" or "--" not in args:
        print(__doc__)
        return 2
    n = int(args[1])
    cmd = args[args.index("--") + 1 :]

    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    lines = []

    def drain_stdout():
        for line in proc.stdout:
            print(f"  server| {line}", end="")
            lines.append(line)

    reader = threading.Thread(target=drain_stdout, daemon=True)
    reader.start()

    try:
        # wait for the bound-address line
        deadline = time.monotonic() + TIMEOUT
        addr = None
        while time.monotonic() < deadline and addr is None:
            for line in list(lines):
                if line.startswith("listening on "):
                    addr = line.split()[2]
                    break
            if proc.poll() is not None:
                fail(f"server exited early with {proc.returncode}")
            time.sleep(0.05)
        if addr is None:
            fail("server never printed its 'listening on' line")
        host, port = addr.rsplit(":", 1)
        print(f"serve smoke: connected to {host}:{port}, n={n}")

        sock = socket.create_connection((host, int(port)), timeout=TIMEOUT)
        sock.settimeout(TIMEOUT)

        m = request(sock, {"op": "metrics"})
        if not m.get("ok"):
            fail(f"metrics refused: {m}")

        x = [((7 * i + 3) % 17 - 8) / 8.0 for i in range(n)]
        fwd = request(sock, {"op": "forward", "signal": x})
        if not fwd.get("ok"):
            fail(f"forward refused: {fwd}")
        y = fwd["signal"]
        if len(y) != n:
            fail(f"forward returned {len(y)} coefficients, want {n}")

        adj = request(sock, {"op": "adjoint", "signal": y})
        if not adj.get("ok"):
            fail(f"adjoint refused: {adj}")
        z = adj["signal"]
        err = max(abs(a - b) for a, b in zip(x, z))
        if err > 1e-3:
            fail(f"adjoint(forward(x)) diverged from x: max |diff| = {err}")
        print(f"serve smoke: round trip max |diff| = {err:.2e}")

        m = request(sock, {"op": "metrics"})["metrics"]
        if m["completed"] < 2:
            fail(f"metrics report {m['completed']} completed, want >= 2")
        if m["errors"] != 0:
            fail(f"metrics report {m['errors']} errors")
        sock.close()

        # graceful drain: SIGTERM, clean exit, "drained:" in the log
        proc.send_signal(signal.SIGTERM)
        try:
            code = proc.wait(timeout=TIMEOUT)
        except subprocess.TimeoutExpired:
            proc.kill()
            fail("server did not drain within the timeout after SIGTERM")
        reader.join(timeout=10)
        if code != 0:
            fail(f"server exited {code} after SIGTERM, want 0")
        if not any(line.startswith("drained:") for line in lines):
            fail("server never printed its 'drained:' summary")
        print("serve smoke: SIGTERM drained cleanly, exit 0")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
