#!/usr/bin/env python3
"""Loopback smoke test for the hardened serving edge (`fastes serve --listen`).

Usage: serve_smoke.py --n N -- <fastes-binary> serve --plan X.fastplan \
           --listen 127.0.0.1:0 [more serve flags]

Launches the server command (the fastes binary directly — not through
`cargo run`, so the SIGTERM below reaches the server and the exit code
is the server's), parses the bound port from its "listening on" line,
then exercises the wire protocol end to end:

  1. `metrics` answers on a fresh connection
  2. `forward` on a deterministic signal returns an n-vector
  3. `adjoint` of that reply round-trips back to the input (the G-chain
     is orthonormal, so synthesis(analysis(x)) ~= x)
  4. `metrics` now reports both transforms completed and zero errors
  5. `filter` with an explicit diagonal response is **bitwise equal**
     to the unfused reference computed client-side: analysis
     coefficients from step 2, scaled in float32 (NumPy when available,
     struct-emulated single-rounding otherwise), synthesized back via
     an `adjoint` request
  6. a kernel `filter` (heat) resolves against the plan's attached
     spectrum and is non-expansive (heat responses lie in (0, 1])
  7. `wavelet` with J scales returns the band-major (J+1)*n stack
  8. `topk` returns ascending indices whose values are bitwise the
     analysis coefficients of step 2, dominating every dropped one
  9. drift leg: a `refactor` request carrying a drifted matrix (built
     over the wire as S' = U diag(d) U^T via explicit-response filter
     requests on the basis vectors, symmetrized in f64) schedules a
     background warm refactorization; an in-flight `forward` submitted
     right behind it must drain on a complete plan (bitwise equal to
     the old plan's reply or the new one's — never a torn mix), and
     `metrics` must eventually show the swapped default checksum with a
     certified `rel_err`
 10. SIGTERM drains gracefully: the process prints "drained:" and
     exits 0 with every in-flight reply already delivered

Steps 5-8 need the served plan to be a version-2 `.fastplan` carrying
its Lemma-1 spectrum (`fastes factor --kind sym --save-plan` and
`fastes gft --save-plan` both write one).

Any hang is bounded by socket/process timeouts; any protocol or
drain failure exits non-zero with a diagnostic.
"""

import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

TIMEOUT = 120.0  # generous: debug builds on loaded CI runners

try:
    import numpy as np
except ImportError:  # struct-based f32 emulation below stays exact
    np = None


def f32(v):
    """Round a float to its nearest binary32, returned as a Python float."""
    return struct.unpack("<f", struct.pack("<f", v))[0]


def f32_mul(a, b):
    """Single-rounded binary32 product — the server's f32 arithmetic.

    The f64 product of two binary32 values is exact (24+24 < 53 mantissa
    bits), so rounding it once to binary32 is bitwise the correctly
    rounded f32 multiply; the NumPy path and the struct fallback agree.
    """
    if np is not None:
        return float(np.float32(a) * np.float32(b))
    return f32(f32(a) * f32(b))


def bits(v):
    """The binary32 bit pattern of a float, for bitwise comparisons."""
    return struct.pack("<f", f32(v))


def send_frame(sock, obj):
    payload = json.dumps(obj).encode()
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_exact(sock, count):
    buf = b""
    while len(buf) < count:
        chunk = sock.recv(count - len(buf))
        if not chunk:
            raise ConnectionError(f"server closed mid-frame ({len(buf)}/{count} bytes)")
        buf += chunk
    return buf


def recv_frame(sock):
    (length,) = struct.unpack("<I", recv_exact(sock, 4))
    return json.loads(recv_exact(sock, length))


def request(sock, obj):
    send_frame(sock, obj)
    return recv_frame(sock)


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main():
    args = sys.argv[1:]
    if len(args) < 3 or args[0] != "--n" or "--" not in args:
        print(__doc__)
        return 2
    n = int(args[1])
    cmd = args[args.index("--") + 1 :]

    proc = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    lines = []

    def drain_stdout():
        for line in proc.stdout:
            print(f"  server| {line}", end="")
            lines.append(line)

    reader = threading.Thread(target=drain_stdout, daemon=True)
    reader.start()

    try:
        # wait for the bound-address line
        deadline = time.monotonic() + TIMEOUT
        addr = None
        while time.monotonic() < deadline and addr is None:
            for line in list(lines):
                if line.startswith("listening on "):
                    addr = line.split()[2]
                    break
            if proc.poll() is not None:
                fail(f"server exited early with {proc.returncode}")
            time.sleep(0.05)
        if addr is None:
            fail("server never printed its 'listening on' line")
        host, port = addr.rsplit(":", 1)
        print(f"serve smoke: connected to {host}:{port}, n={n}")

        sock = socket.create_connection((host, int(port)), timeout=TIMEOUT)
        sock.settimeout(TIMEOUT)

        m = request(sock, {"op": "metrics"})
        if not m.get("ok"):
            fail(f"metrics refused: {m}")

        x = [((7 * i + 3) % 17 - 8) / 8.0 for i in range(n)]
        fwd = request(sock, {"op": "forward", "signal": x})
        if not fwd.get("ok"):
            fail(f"forward refused: {fwd}")
        y = fwd["signal"]
        if len(y) != n:
            fail(f"forward returned {len(y)} coefficients, want {n}")

        adj = request(sock, {"op": "adjoint", "signal": y})
        if not adj.get("ok"):
            fail(f"adjoint refused: {adj}")
        z = adj["signal"]
        err = max(abs(a - b) for a, b in zip(x, z))
        if err > 1e-3:
            fail(f"adjoint(forward(x)) diverged from x: max |diff| = {err}")
        print(f"serve smoke: round trip max |diff| = {err:.2e}")

        m = request(sock, {"op": "metrics"})["metrics"]
        if m["completed"] < 2:
            fail(f"metrics report {m['completed']} completed, want >= 2")
        if m["errors"] != 0:
            fail(f"metrics report {m['errors']} errors")

        # ---- fused filter vs unfused loopback reference, bitwise ----
        # `forward` is the analysis GFT, so y above is x-hat = U^T x.
        # The fused filter is U diag(h) U^T x; the unfused reference is
        # one client-side f32 diagonal scale of x-hat synthesized back
        # through an `adjoint` request. Every traversal runs on the
        # server, so fused-vs-unfused is isolated to the fusion itself.
        xhat = y
        h = [((3 * i) % 9 - 4) / 4.0 for i in range(n)]  # exact in f32
        scaled = [f32_mul(c, hi) for c, hi in zip(xhat, h)]
        ref = request(sock, {"op": "adjoint", "signal": scaled})
        if not ref.get("ok"):
            fail(f"reference synthesis refused: {ref}")
        want = ref["signal"]

        flt = request(sock, {"op": "filter", "signal": x, "response": h})
        if not flt.get("ok"):
            fail(f"filter refused: {flt}")
        got = flt["signal"]
        if len(got) != n:
            fail(f"filter returned {len(got)} values, want {n}")
        diverged = [i for i in range(n) if bits(got[i]) != bits(want[i])]
        if diverged:
            i = diverged[0]
            fail(
                f"fused filter diverged bitwise from the unfused reference at "
                f"{len(diverged)}/{n} indices (first: [{i}] {got[i]} != {want[i]})"
            )
        print(f"serve smoke: fused filter == unfused reference bitwise ({n} values)")

        # ---- kernel filter resolved on the plan's spectrum ----
        kflt = request(sock, {"op": "filter", "signal": x, "kernel": "heat", "param": 0.5})
        if not kflt.get("ok"):
            fail(f"kernel filter refused (plan missing its spectrum?): {kflt}")
        if len(kflt["signal"]) != n:
            fail(f"kernel filter returned {len(kflt['signal'])} values, want {n}")
        ein = sum(f32(v) ** 2 for v in x)
        eout = sum(f32(v) ** 2 for v in kflt["signal"])
        if eout > ein * (1.0 + 1e-3):
            fail(f"heat filter expanded signal energy: {eout} > {ein}")
        print(f"serve smoke: heat kernel filter ok (energy {eout:.3f} <= {ein:.3f})")

        # ---- wavelet bank: band-major (J+1)*n stack ----
        scales = 2
        wav = request(sock, {"op": "wavelet", "signal": x, "scales": scales})
        if not wav.get("ok"):
            fail(f"wavelet refused: {wav}")
        if len(wav["signal"]) != (scales + 1) * n:
            fail(
                f"wavelet reply has {len(wav['signal'])} values, "
                f"want (J+1)*n = {(scales + 1) * n}"
            )
        print(f"serve smoke: wavelet bank returned {scales + 1} bands of {n}")

        # ---- top-k: sparse spectral payload consistent with x-hat ----
        k = 8
        top = request(sock, {"op": "topk", "signal": x, "k": k})
        if not top.get("ok"):
            fail(f"topk refused: {top}")
        idx, vals = top["indices"], top["values"]
        if len(idx) != len(vals) or len(idx) > k:
            fail(f"topk payload malformed: {len(idx)} indices / {len(vals)} values")
        if idx != sorted(idx):
            fail(f"topk indices not ascending: {idx}")
        for i, v in zip(idx, vals):
            if bits(v) != bits(xhat[i]):
                fail(f"topk value at spectral index {i} is {v}, want coefficient {xhat[i]}")
        kept = set(idx)
        floor = min((abs(f32(v)) for v in vals), default=0.0)
        worst = max((abs(f32(c)) for i, c in enumerate(xhat) if i not in kept), default=0.0)
        if len(idx) == k and worst > floor:
            fail(f"topk dropped a coefficient of magnitude {worst} > kept floor {floor}")
        print(f"serve smoke: topk kept {len(idx)}/{n} coefficients, bitwise-consistent")

        m = request(sock, {"op": "metrics"})["metrics"]
        if m["completed"] < 7:
            fail(f"metrics report {m['completed']} completed, want >= 7")
        if m["errors"] != 0:
            fail(f"metrics report {m['errors']} errors after spectral ops")

        # ---- drift leg: background warm refactor + zero-downtime swap ----
        reg = m.get("registry") or {}
        old_key = reg.get("default_checksum")
        if old_key is None:
            fail("drift leg: metrics carry no registry default checksum")
        # Build a drifted matrix the served chain still nearly
        # diagonalizes: S' = U diag(d) U^T, one column per
        # explicit-response filter request on a basis vector, then
        # symmetrized in f64 (the replies are f32-rounded).
        d = [1.5 + 0.25 * i for i in range(n)]
        cols = []
        for j in range(n):
            e = [0.0] * n
            e[j] = 1.0
            r = request(sock, {"op": "filter", "signal": e, "response": d})
            if not r.get("ok"):
                fail(f"drift leg: basis filter request refused: {r}")
            cols.append(r["signal"])
        matrix = [
            (cols[j][i] + cols[i][j]) / 2.0 for i in range(n) for j in range(n)
        ]
        sched = request(sock, {"op": "refactor", "matrix": matrix})
        if not sched.get("ok") or sched.get("status") != "scheduled":
            fail(f"drift leg: refactor was not scheduled: {sched}")
        # an in-flight request racing the background swap must drain on a
        # complete plan: its reply is bitwise the old plan's answer or the
        # new plan's answer, never a torn mix (y is the old plan's
        # forward of x from step 2)
        mid = request(sock, {"op": "forward", "signal": x})
        if not mid.get("ok") or len(mid["signal"]) != n:
            fail(f"drift leg: in-flight forward failed during refactor: {mid}")
        deadline = time.monotonic() + TIMEOUT
        new_key = new_rel = None
        while time.monotonic() < deadline:
            reg = request(sock, {"op": "metrics"})["metrics"]["registry"]
            key = reg.get("default_checksum")
            if key and key != old_key:
                new_key = key
                for p in reg.get("plans", []):
                    if p.get("checksum") == key:
                        new_rel = p.get("rel_err")
                break
            time.sleep(0.1)
        if new_key is None:
            fail("drift leg: background refactor never swapped the default plan")
        if new_rel is None or not (0.0 <= new_rel < 1.0):
            fail(f"drift leg: swapped-in plan has no certified rel_err: {new_rel}")
        post = request(sock, {"op": "forward", "signal": x})
        if not post.get("ok"):
            fail(f"drift leg: post-swap forward refused: {post}")
        mid_bits = [bits(v) for v in mid["signal"]]
        old_bits = [bits(v) for v in y]
        post_bits = [bits(v) for v in post["signal"]]
        if mid_bits != old_bits and mid_bits != post_bits:
            fail(
                "drift leg: in-flight reply matches neither the old plan's "
                "answer nor the new plan's — torn across the swap"
            )
        which = "old" if mid_bits == old_bits else "new"
        print(
            f"serve smoke: drift refactor hot-swapped {old_key} -> {new_key} "
            f"(rel_err {new_rel:.2e}); in-flight reply drained on the {which} plan"
        )

        m = request(sock, {"op": "metrics"})["metrics"]
        if m["errors"] != 0:
            fail(f"metrics report {m['errors']} errors after the drift leg")
        sock.close()

        # graceful drain: SIGTERM, clean exit, "drained:" in the log
        proc.send_signal(signal.SIGTERM)
        try:
            code = proc.wait(timeout=TIMEOUT)
        except subprocess.TimeoutExpired:
            proc.kill()
            fail("server did not drain within the timeout after SIGTERM")
        reader.join(timeout=10)
        if code != 0:
            fail(f"server exited {code} after SIGTERM, want 0")
        if not any(line.startswith("drained:") for line in lines):
            fail("server never printed its 'drained:' summary")
        print("serve smoke: SIGTERM drained cleanly, exit 0")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
